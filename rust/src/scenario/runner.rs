//! The sharded deterministic scenario runner.
//!
//! A batch is `scenarios × seeds`; every cell is an independent TOLA
//! learning run whose RNG streams derive from `(base_seed, scenario name,
//! replicate)` alone — never from cell order or thread assignment — so a
//! batch fanned across [`parallel_map`] is bit-identical under any
//! `--threads`. Within a cell the PR-1 structure-sharing sweep engine
//! evaluates the counterfactual grid single-threaded; parallelism comes
//! from sharding cells across the worker pool.

use anyhow::{bail, Result};

use crate::coordinator::{parallel_map, tola_run_view_traced, Evaluator};
use crate::feed;
use crate::telemetry::Telemetry;
use crate::learning::counterfactual::CfSpec;
use crate::learning::replay_specs;
use crate::market::{
    replay, MarketOffer, MarketView, PriceTrace, SpotPriceProcess, SLOTS_PER_UNIT,
};
use crate::policy::routing::RoutingPolicy;
use crate::policy::{benchmark_bids, grid_b, policy_set_full, policy_set_spot_only};
use crate::util::rng::SplitMix64;
use crate::workload::{transform, ArrivalSchedule, ChainJob, GeneratorConfig, MixStream};

use super::spec::{PolicySetSpec, PriceSpec, ReplayFormat, RoutingSpec, ScenarioSpec};

/// Batch-level options for [`run_batch`].
#[derive(Debug, Clone)]
pub struct BatchOptions {
    /// Replicates per scenario.
    pub seeds: u64,
    /// The user-facing seed every run seed derives from.
    pub base_seed: u64,
    /// Worker threads the cells shard across (affects wall-clock only).
    pub threads: usize,
    /// Override each scenario's job count (smoke / --jobs).
    pub jobs_override: Option<usize>,
    /// Observability handle shared by every cell. Cells record into
    /// per-cell sources (`"{scenario}#{replicate}"`) flushed through the
    /// handle, so the canonical event log is independent of cell/thread
    /// scheduling; outcomes are byte-identical with telemetry on or off.
    pub telemetry: Telemetry,
}

/// The metrics one scenario run produces.
#[derive(Debug, Clone)]
pub struct ScenarioOutcome {
    pub scenario: String,
    pub replicate: u64,
    pub run_seed: u64,
    pub jobs: usize,
    /// Realized average unit cost ᾱ.
    pub average_unit_cost: f64,
    pub average_regret: f64,
    pub regret_bound: f64,
    pub pool_utilization: f64,
    /// Work-share per instance kind (fractions of total processed work).
    pub so_share: f64,
    pub spot_share: f64,
    pub od_share: f64,
    /// Realized spot availability over the horizon at the lowest / highest
    /// §6.1 grid bid.
    pub availability_lo: f64,
    pub availability_hi: f64,
    /// Label of the highest-weight policy at the end of the run.
    pub best_policy: String,
    /// Cloud-work share per `(offer label, share)` for routed multi-offer
    /// worlds; empty for degenerate (single-offer) markets, so legacy
    /// report rows are byte-identical.
    pub offer_shares: Vec<(String, f64)>,
    /// Mean counterfactual cost per job of every *fixed* policy in the
    /// run's grid, as `(label, mean cost)` pairs in spec order — what the
    /// fleet layer's cross-scenario robustness scoring compares across
    /// worlds (serialized per report row, see
    /// [`crate::scenario::report`]).
    pub policy_costs: Vec<(String, f64)>,
    /// The spec's regime tags, copied verbatim so the fleet layer can
    /// group worlds for the cross-regime promotion gate
    /// ([`crate::robustness::gate`]). Empty for untagged worlds — and
    /// omitted from report rows, keeping legacy rows byte-identical.
    pub tags: Vec<String>,
    /// Per-policy capacity-replay optimism gap (`replayed − free` mean
    /// cost, always ≥ 0) as `(label, gap)` pairs in spec order — see
    /// [`crate::learning::replay`]. Only computed for worlds with at least
    /// one capacity-capped offer; empty (and omitted from report rows)
    /// otherwise, so capacity-free rows keep the legacy byte shape.
    pub optimism_gap: Vec<(String, f64)>,
    /// Mid-window migrations the executed (learning) stream performed.
    /// Always 0 when the spec's migration policy is disabled — the key is
    /// omitted from report rows then, keeping legacy rows byte-identical.
    pub migrations: u64,
}

/// Deterministic per-run seed: FNV-1a over the scenario name folded with
/// the base seed and replicate index through SplitMix64. Cell order and
/// thread count cannot influence any run's streams.
pub fn derive_run_seed(base_seed: u64, scenario: &str, replicate: u64) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in scenario.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    let mut sm = SplitMix64::new(
        h ^ base_seed.rotate_left(17) ^ replicate.wrapping_mul(0xA24B_AED4_963E_E407),
    );
    sm.next_u64()
}

/// Build one region's realized [`PriceTrace`] for the horizon. Public so
/// the robustness derivation operators ([`crate::robustness::derive`]) can
/// materialize base-world traces before resampling them.
pub fn region_trace(price: &PriceSpec, horizon: f64, seed: u64) -> Result<PriceTrace> {
    match price {
        PriceSpec::Model(m) => Ok(PriceTrace::generate(m.clone(), horizon, seed)),
        PriceSpec::Regimes(segments) => {
            let slot_len = 1.0 / SLOTS_PER_UNIT as f64;
            let total = (horizon / slot_len).ceil() as usize + 1;
            // One persistent process per regime: Markov/RNG state carries
            // across cycles of the schedule.
            let mut procs: Vec<SpotPriceProcess> = segments
                .iter()
                .enumerate()
                .map(|(k, (_, m))| {
                    SpotPriceProcess::new(m.clone(), seed ^ (k as u64 + 1).wrapping_mul(0x9E37))
                })
                .collect();
            let mut prices = Vec::with_capacity(total);
            let mut seg = 0usize;
            let mut remaining = segments[0].0;
            while prices.len() < total {
                if remaining <= 0.0 {
                    seg = (seg + 1) % segments.len();
                    remaining = segments[seg].0;
                }
                prices.push(procs[seg].next_price());
                remaining -= slot_len;
            }
            Ok(PriceTrace::from_prices(prices, slot_len))
        }
        PriceSpec::Replay(r) => {
            let trace = match r.format {
                ReplayFormat::Simple => match (&r.csv, &r.path) {
                    (Some(text), _) => replay::trace_from_csv_opts(
                        text,
                        r.time_scale,
                        r.price_scale,
                        r.normalize,
                    )?,
                    (None, Some(path)) => replay::trace_from_csv_file_opts(
                        path,
                        r.time_scale,
                        r.price_scale,
                        r.normalize,
                    )?,
                    (None, None) => bail!("replay spec has neither csv nor path"),
                },
                // EC2 dump shapes go through the streaming loaders (which
                // normalize out-of-order records) and materialize onto the
                // standard grid. The spec's az/instance_type filters select
                // one series out of a multi-series dump; without them the
                // loaders keep erroring with the candidate series listed.
                ec2 => {
                    let fmt = match ec2 {
                        ReplayFormat::Ec2Json => feed::FeedFormat::Ec2Json,
                        _ => feed::FeedFormat::Csv,
                    };
                    let filter = feed::FeedFilter {
                        availability_zone: r.az.clone(),
                        instance_type: r.instance_type.clone(),
                    };
                    let load = match (&r.csv, &r.path) {
                        (Some(text), _) => feed::load_events(
                            text,
                            fmt,
                            &filter,
                            r.time_scale,
                            r.price_scale,
                        )?,
                        (None, Some(path)) => feed::load_events_file(
                            path,
                            Some(fmt),
                            &filter,
                            r.time_scale,
                            r.price_scale,
                        )?,
                        (None, None) => bail!("replay spec has neither csv nor path"),
                    };
                    feed::events_to_trace(&load.events, 1.0 / SLOTS_PER_UNIT as f64)?
                }
            };
            Ok(if r.tile {
                replay::tile_to_horizon(&trace, horizon)
            } else {
                trace
            })
        }
    }
}

/// Realize the scenario's market over `horizon` into a capacity-aware
/// [`MarketView`], plus the runtime routing policy for multi-offer views.
///
/// * `home` routing realizes only offer 0 (the rest are inert — don't pay
///   to generate their traces) and yields a one-offer view;
/// * `arbitrage` realizes every offer and collapses them into the
///   slot-wise cheapest composite — again a one-offer view, so the
///   coordinator takes the bit-identical single-trace path;
/// * `cheapest` / `spillover` realize every flattened
///   `(region, instance_type)` offer with per-offer derived seeds and keep
///   them separate for real routing.
pub fn build_market_view(
    spec: &ScenarioSpec,
    horizon: f64,
    seed: u64,
) -> Result<(MarketView, RoutingPolicy)> {
    let offers = spec.market.flattened_offers();
    let wanted = match spec.market.routing {
        RoutingSpec::Home => 1,
        _ => offers.len(),
    };
    let realized: Vec<MarketOffer> = offers
        .iter()
        .take(wanted)
        .enumerate()
        .map(|(k, o)| {
            Ok(MarketOffer {
                region: o.region.clone(),
                instance_type: o.instance_type.clone(),
                od_price: o.od_price,
                trace: region_trace(&o.price, horizon, seed ^ ((k as u64 + 1) << 8))?,
                capacity: o.capacity,
            })
        })
        .collect::<Result<_>>()?;
    let view = MarketView::new(realized)?;
    match spec.market.routing.runtime() {
        None => {
            // Arbitrage: collapse to the composite one-offer view.
            let (trace, od) = view.arbitrage_collapse()?;
            Ok((MarketView::single(trace, od), RoutingPolicy::Home))
        }
        Some(routing) => Ok((view, routing)),
    }
}

/// Realize the scenario's market as the legacy `(trace, od_price)` pair —
/// only defined for worlds that collapse to one offer (home or arbitrage
/// routing). Routed multi-offer worlds error: use [`build_market_view`].
pub fn build_market(spec: &ScenarioSpec, horizon: f64, seed: u64) -> Result<(PriceTrace, f64)> {
    let (view, _) = build_market_view(spec, horizon, seed)?;
    if view.len() > 1 {
        bail!(
            "scenario '{}' routes across {} offers; use build_market_view",
            spec.name,
            view.len()
        );
    }
    let offer = view.offers()[0].clone();
    Ok((offer.trace, offer.od_price))
}

/// Realize the scenario's workload: `jobs` chain jobs from the weighted mix
/// under the arrival schedule.
pub fn build_workload(spec: &ScenarioSpec, jobs: usize, seed: u64) -> Vec<ChainJob> {
    let components: Vec<(GeneratorConfig, f64)> = spec
        .workload
        .components
        .iter()
        .map(|c| {
            let mut g = GeneratorConfig::for_job_type(c.job_type);
            if spec.workload.small_tasks {
                g.task_counts = vec![3, 7];
            }
            (g, c.weight)
        })
        .collect();
    let schedule = ArrivalSchedule {
        base_rate: spec.workload.arrival_rate,
        phases: spec.workload.rate_phases.clone(),
    };
    let mut stream = MixStream::new(components, schedule, seed);
    stream.take_jobs(jobs).iter().map(transform).collect()
}

/// Resolve the scenario's policy grid into counterfactual specs (shared
/// with the `repro feed` driver, which takes its workload and policy set
/// from a scenario but its market from the feed).
pub fn cf_specs(spec: &ScenarioSpec) -> Vec<CfSpec> {
    let set = match spec.policy_set {
        PolicySetSpec::Auto if spec.pool_capacity > 0 => PolicySetSpec::Full,
        PolicySetSpec::Auto => PolicySetSpec::SpotOnly,
        s => s,
    };
    match set {
        PolicySetSpec::SpotOnly => policy_set_spot_only()
            .into_iter()
            .map(CfSpec::Proposed)
            .collect(),
        PolicySetSpec::Full => policy_set_full().into_iter().map(CfSpec::Proposed).collect(),
        PolicySetSpec::Benchmark => benchmark_bids()
            .into_iter()
            .map(|b| CfSpec::EvenNaive { bid: b })
            .collect(),
        PolicySetSpec::Auto => unreachable!("resolved above"),
    }
}

/// Run one scenario cell: realize workload and market from the run seed,
/// execute the TOLA learning loop, and distill the comparable metrics.
///
/// Worlds that collapse to one offer (home / arbitrage routing) take the
/// coordinator's bit-identical legacy path; routed worlds place every task
/// against remaining offer capacity. Availability metrics are always
/// measured on the effective home offer (the composite for arbitrage),
/// keeping rows comparable across worlds.
pub fn run_scenario_once(
    spec: &ScenarioSpec,
    run_seed: u64,
    jobs_override: Option<usize>,
) -> Result<ScenarioOutcome> {
    run_scenario_once_traced(
        spec,
        run_seed,
        jobs_override,
        &Telemetry::disabled(),
        &format!("{}#0", spec.name),
    )
}

/// [`run_scenario_once`] recording telemetry under the given event-log
/// source (by convention `"{scenario}#{replicate}"`, which is unique per
/// batch cell). The learning run itself is bit-identical either way.
pub fn run_scenario_once_traced(
    spec: &ScenarioSpec,
    run_seed: u64,
    jobs_override: Option<usize>,
    tele: &Telemetry,
    source: &str,
) -> Result<ScenarioOutcome> {
    spec.validate()?;
    let n_jobs = jobs_override.unwrap_or(spec.jobs);
    let jobs = build_workload(spec, n_jobs, run_seed ^ 0x10AD);
    let horizon = jobs.iter().map(|j| j.deadline).fold(1.0, f64::max) + 1.0;
    let (view, routing) = build_market_view(spec, horizon, run_seed ^ 0x7ACE)?;
    let specs = cf_specs(spec);
    let mut rec = tele.recorder(source);
    let cell_span = tele.span("runner/cell");
    let rep = tola_run_view_traced(
        &jobs,
        &specs,
        &view,
        routing,
        spec.migration,
        spec.pool_capacity,
        run_seed ^ 0x701A_2,
        &Evaluator::Native { threads: 1 },
        tele,
        &mut rec,
    );
    drop(cell_span);
    tele.absorb(rec);

    // Capacity replay: re-run every policy's capacity-free allocations
    // through a real ledger and report the optimism gap. Only meaningful
    // (and only computed) when some offer is capacity-capped; gating on
    // that keeps capacity-free rows byte-identical to the legacy schema.
    let optimism_gap: Vec<(String, f64)> = if view.has_finite_capacity() {
        let replay_span = tele.span("runner/replay");
        let rows = replay_specs(&jobs, &specs, &view, routing, spec.pool_capacity > 0);
        drop(replay_span);
        rows.into_iter().map(|r| { let gap = r.gap(); (r.label, gap) }).collect()
    } else {
        Vec::new()
    };

    let grid = grid_b();
    let lo_bid = grid.first().copied().unwrap_or(0.18);
    let hi_bid = grid.last().copied().unwrap_or(0.3);
    let trace = &view.home().trace;
    let t1 = (trace.horizon() - 1e-9).max(0.0);
    let total_work = rep.ledger.total_work().max(1e-12);
    let offer_shares = if view.len() > 1 {
        let cloud: f64 = rep.offer_work.iter().sum::<f64>().max(1e-12);
        view.offers()
            .iter()
            .zip(&rep.offer_work)
            .map(|(o, &w)| (o.label(), w / cloud))
            .collect()
    } else {
        Vec::new()
    };
    Ok(ScenarioOutcome {
        scenario: spec.name.clone(),
        replicate: 0, // filled by run_batch
        run_seed,
        jobs: rep.jobs,
        average_unit_cost: rep.average_unit_cost,
        average_regret: rep.average_regret,
        regret_bound: rep.regret_bound,
        pool_utilization: rep.pool_utilization,
        so_share: rep.ledger.work_selfowned / total_work,
        spot_share: rep.ledger.work_spot / total_work,
        od_share: rep.ledger.work_ondemand / total_work,
        availability_lo: trace.availability(0.0, t1, lo_bid),
        availability_hi: trace.availability(0.0, t1, hi_bid),
        best_policy: specs[rep.best_policy].label(),
        offer_shares,
        policy_costs: specs
            .iter()
            .map(|s| s.label())
            .zip(rep.policy_mean_costs.iter().copied())
            .collect(),
        tags: spec.tags.clone(),
        optimism_gap,
        migrations: rep.migrations,
    })
}

/// Run `specs × opts.seeds` cells across the worker pool. Outcomes come
/// back in deterministic `(scenario, replicate)` order regardless of thread
/// count; any cell error fails the batch.
pub fn run_batch(specs: &[ScenarioSpec], opts: &BatchOptions) -> Result<Vec<ScenarioOutcome>> {
    let reps = opts.seeds.max(1);
    let mut cells: Vec<(usize, u64)> = Vec::new();
    for i in 0..specs.len() {
        for rep in 0..reps {
            cells.push((i, rep));
        }
    }
    let results: Vec<Result<ScenarioOutcome>> = parallel_map(cells.len(), opts.threads, |k| {
        let (i, rep) = cells[k];
        let spec = &specs[i];
        run_scenario_once_traced(
            spec,
            derive_run_seed(opts.base_seed, &spec.name, rep),
            opts.jobs_override,
            &opts.telemetry,
            &format!("{}#{}", spec.name, rep),
        )
        .map(|mut o| {
            o.replicate = rep;
            o
        })
    });
    results.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::market::SpotModel;
    use crate::scenario::spec::{MarketSpec, ReplaySpec, WorkloadSpec};

    fn tiny(name: &str) -> ScenarioSpec {
        let mut w = WorkloadSpec::uniform(2);
        w.small_tasks = true;
        ScenarioSpec {
            name: name.into(),
            description: String::new(),
            market: MarketSpec::single(SpotModel::paper_default(), 1.0),
            workload: w,
            pool_capacity: 0,
            policy_set: PolicySetSpec::Auto,
            jobs: 12,
            tags: Vec::new(),
            migration: crate::policy::routing::MigrationPolicy::disabled(),
        }
    }

    #[test]
    fn run_seed_depends_on_all_inputs() {
        let a = derive_run_seed(7, "x", 0);
        assert_eq!(a, derive_run_seed(7, "x", 0));
        assert_ne!(a, derive_run_seed(8, "x", 0));
        assert_ne!(a, derive_run_seed(7, "y", 0));
        assert_ne!(a, derive_run_seed(7, "x", 1));
    }

    #[test]
    fn single_run_is_reproducible() {
        let spec = tiny("repro");
        let s = derive_run_seed(3, &spec.name, 0);
        let a = run_scenario_once(&spec, s, None).unwrap();
        let b = run_scenario_once(&spec, s, None).unwrap();
        assert_eq!(a.average_unit_cost, b.average_unit_cost);
        assert_eq!(a.average_regret, b.average_regret);
        assert_eq!(a.best_policy, b.best_policy);
        assert_eq!(a.jobs, 12);
    }

    #[test]
    fn batch_order_and_values_are_thread_invariant() {
        let specs = vec![tiny("a"), tiny("b")];
        let run = |threads| {
            run_batch(
                &specs,
                &BatchOptions {
                    seeds: 2,
                    base_seed: 5,
                    threads,
                    jobs_override: Some(8),
                    telemetry: Telemetry::disabled(),
                },
            )
            .unwrap()
        };
        let one = run(1);
        let eight = run(8);
        assert_eq!(one.len(), 4);
        for (x, y) in one.iter().zip(&eight) {
            assert_eq!(x.scenario, y.scenario);
            assert_eq!(x.replicate, y.replicate);
            assert_eq!(x.run_seed, y.run_seed);
            assert_eq!(x.average_unit_cost, y.average_unit_cost);
            assert_eq!(x.average_regret, y.average_regret);
        }
    }

    #[test]
    fn replay_market_flows_end_to_end() {
        // Constant cheap price 0.2: every grid bid ≥ 0.21 always wins, so
        // the learner should end far below on-demand cost.
        let mut spec = tiny("replay-e2e");
        spec.market.regions[0].price =
            PriceSpec::Replay(ReplaySpec::inline("time,price\n0,0.2\n10,0.2\n"));
        let out = run_scenario_once(&spec, derive_run_seed(1, "replay-e2e", 0), None).unwrap();
        assert!(
            out.availability_hi > 0.999,
            "bid 0.3 vs constant 0.2 price: availability {}",
            out.availability_hi
        );
        assert!(
            out.average_unit_cost < 0.75,
            "alpha {} should sit well below on-demand 1.0",
            out.average_unit_cost
        );
        assert!(out.spot_share > 0.1, "spot share {}", out.spot_share);
    }

    #[test]
    fn pool_scenario_reports_utilization() {
        let mut spec = tiny("pooled");
        spec.pool_capacity = 150;
        let out = run_scenario_once(&spec, derive_run_seed(2, "pooled", 0), None).unwrap();
        assert!(out.so_share > 0.0, "self-owned share {}", out.so_share);
        assert!(out.pool_utilization > 0.0);
        assert!(out.best_policy.starts_with("proposed"));
    }

    #[test]
    fn routed_world_cell_reports_offer_shares() {
        let mut spec = crate::scenario::registry::find("multi-region-routed").unwrap();
        spec.workload.small_tasks = true;
        let out = run_scenario_once(
            &spec,
            derive_run_seed(5, "multi-region-routed", 0),
            Some(24),
        )
        .unwrap();
        assert_eq!(out.offer_shares.len(), 3, "one share per flattened offer");
        let total: f64 = out.offer_shares.iter().map(|(_, s)| s).sum();
        assert!((total - 1.0).abs() < 1e-6, "shares sum to {total}");
        assert!(out.offer_shares[0].0.contains("us-east"));
        let shares = out.so_share + out.spot_share + out.od_share;
        assert!((shares - 1.0).abs() < 1e-6);
    }

    #[test]
    fn degenerate_world_reports_no_offer_shares() {
        let spec = tiny("degenerate");
        let out = run_scenario_once(&spec, derive_run_seed(6, "degenerate", 0), None).unwrap();
        assert!(out.offer_shares.is_empty(), "legacy rows must not change shape");
    }

    #[test]
    fn build_market_errors_on_routed_worlds() {
        let spec = crate::scenario::registry::find("multi-region-routed").unwrap();
        let err = build_market(&spec, 10.0, 1).unwrap_err().to_string();
        assert!(err.contains("build_market_view"), "{err}");
        // But stays defined for home and arbitrage worlds.
        assert!(build_market(&tiny("t"), 10.0, 1).is_ok());
        let arb = crate::scenario::registry::find("multi-region-arbitrage").unwrap();
        assert!(build_market(&arb, 10.0, 1).is_ok());
    }

    #[test]
    fn cell_reports_per_policy_costs_with_labels() {
        let spec = tiny("costs");
        let out = run_scenario_once(&spec, derive_run_seed(9, "costs", 0), None).unwrap();
        // Spot-only auto grid: 25 policies, every mean cost finite and
        // bounded by the worst counterfactual (all-on-demand = 1.0/unit
        // times the per-job workload, so just sanity-check shape + order).
        assert_eq!(out.policy_costs.len(), 25);
        let labels: Vec<&str> = out.policy_costs.iter().map(|(l, _)| l.as_str()).collect();
        assert!(labels.iter().all(|l| l.starts_with("proposed")));
        assert!(out.policy_costs.iter().all(|(_, c)| c.is_finite() && *c >= 0.0));
        // The realized best policy's label is one of the scored labels.
        assert!(labels.contains(&out.best_policy.as_str()));
        // The minimum scored cost is consistent with non-negative regret.
        let min = out.policy_costs.iter().map(|(_, c)| *c).fold(f64::INFINITY, f64::min);
        assert!(min.is_finite());
        assert!(out.average_regret >= -1e-9, "regret {}", out.average_regret);
    }

    /// Two interleaved (zone, instance type) series: without a filter the
    /// loaders refuse with the candidates listed; with the spec-level `az`
    /// filter one series realizes into a trace.
    const TWO_SERIES_JSONL: &str = "\
{\"Timestamp\":\"2024-03-01T00:00:00Z\",\"SpotPrice\":\"0.2\",\"AvailabilityZone\":\"us-east-1a\",\"InstanceType\":\"m5.large\"}\n\
{\"Timestamp\":\"2024-03-01T00:00:00Z\",\"SpotPrice\":\"0.6\",\"AvailabilityZone\":\"us-east-1b\",\"InstanceType\":\"m5.large\"}\n\
{\"Timestamp\":\"2024-03-05T00:00:00Z\",\"SpotPrice\":\"0.25\",\"AvailabilityZone\":\"us-east-1a\",\"InstanceType\":\"m5.large\"}\n\
{\"Timestamp\":\"2024-03-05T00:00:00Z\",\"SpotPrice\":\"0.65\",\"AvailabilityZone\":\"us-east-1b\",\"InstanceType\":\"m5.large\"}\n";

    #[test]
    fn replay_spec_series_filter_selects_one_series() {
        let mut rp = ReplaySpec::inline(TWO_SERIES_JSONL);
        rp.format = crate::scenario::ReplayFormat::Ec2Json;
        rp.time_scale = 1.0 / 3600.0;
        // Unfiltered: the multi-series refusal propagates, naming both.
        let err = region_trace(&PriceSpec::Replay(rp.clone()), 10.0, 1)
            .unwrap_err()
            .to_string();
        assert!(err.contains("us-east-1a") && err.contains("us-east-1b"), "{err}");
        // Filtered: the cheap 1a series realizes (constant ~0.2 band).
        rp.az = Some("us-east-1a".into());
        let trace = region_trace(&PriceSpec::Replay(rp), 10.0, 1).unwrap();
        let hi = (0..trace.num_slots())
            .map(|k| trace.price_of_slot(k))
            .fold(0.0, f64::max);
        assert!(hi < 0.3, "filter picked the wrong series: max price {hi}");
    }

    #[test]
    fn regime_schedule_produces_mixed_prices() {
        let calm = SpotModel::BoundedExp {
            mean: 0.13,
            lo: 0.12,
            hi: 0.3,
        };
        let surge = SpotModel::BoundedExp {
            mean: 0.7,
            lo: 0.5,
            hi: 1.0,
        };
        let trace = region_trace(
            &PriceSpec::Regimes(vec![(4.0, calm), (4.0, surge)]),
            40.0,
            9,
        )
        .unwrap();
        let n = trace.num_slots();
        let low = (0..n).filter(|&s| trace.price_of_slot(s) <= 0.3).count();
        let high = (0..n).filter(|&s| trace.price_of_slot(s) >= 0.5).count();
        // Half the schedule in each regime.
        assert!(low as f64 > 0.4 * n as f64, "low {low}/{n}");
        assert!(high as f64 > 0.4 * n as f64, "high {high}/{n}");
    }
}
