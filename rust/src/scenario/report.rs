//! Scenario report: fold a batch of [`ScenarioOutcome`]s into one
//! comparable JSON table (per-run rows plus per-scenario aggregates).
//!
//! The report is a pure function of the outcomes — no timestamps, no
//! environment — so byte-identical batches produce byte-identical JSON
//! (the determinism contract `repro scenarios` is tested against). The
//! schema (`dagcloud.scenarios/v1`, documented field-by-field in
//! `docs/SCHEMAS.md`) is aggregation-friendly on purpose: every detail row
//! is keyed by `(scenario, replicate)` and round-trips losslessly through
//! [`outcomes_from_report`], which is what lets the fleet layer
//! ([`crate::fleet`]) merge shard reports back into one document.

use anyhow::{anyhow, bail, ensure, Result};

use crate::util::json::Json;
use crate::util::stats::Welford;

use super::runner::ScenarioOutcome;

/// Per-scenario aggregate across replicates.
#[derive(Debug, Clone)]
pub struct ScenarioAggregate {
    pub scenario: String,
    pub runs: usize,
    pub alpha_mean: f64,
    pub alpha_std: f64,
    pub regret_mean: f64,
    pub regret_bound_mean: f64,
    pub pool_utilization_mean: f64,
    pub so_share_mean: f64,
    pub spot_share_mean: f64,
    pub od_share_mean: f64,
    pub availability_lo_mean: f64,
    pub availability_hi_mean: f64,
    /// Mean capacity-replay optimism gap across every policy and run
    /// (`None` for capacity-free worlds, where no replay ran — the key
    /// stays off-disk so legacy sections are byte-identical).
    pub optimism_gap_mean: Option<f64>,
    /// Total mid-window migrations across the scenario's runs (omitted
    /// from the serialized section when zero).
    pub migrations_total: u64,
}

/// Aggregate outcomes per scenario, preserving first-seen scenario order.
pub fn aggregate(outcomes: &[ScenarioOutcome]) -> Vec<ScenarioAggregate> {
    let mut order: Vec<&str> = Vec::new();
    for o in outcomes {
        if !order.contains(&o.scenario.as_str()) {
            order.push(&o.scenario);
        }
    }
    order
        .into_iter()
        .map(|name| {
            let runs: Vec<&ScenarioOutcome> =
                outcomes.iter().filter(|o| o.scenario == name).collect();
            let mut alpha = Welford::new();
            let fold = |f: fn(&ScenarioOutcome) -> f64| {
                runs.iter().map(|&o| f(o)).sum::<f64>() / runs.len() as f64
            };
            for o in &runs {
                alpha.push(o.average_unit_cost);
            }
            ScenarioAggregate {
                scenario: name.to_string(),
                runs: runs.len(),
                alpha_mean: alpha.mean(),
                alpha_std: alpha.stddev(),
                regret_mean: fold(|o| o.average_regret),
                regret_bound_mean: fold(|o| o.regret_bound),
                pool_utilization_mean: fold(|o| o.pool_utilization),
                so_share_mean: fold(|o| o.so_share),
                spot_share_mean: fold(|o| o.spot_share),
                od_share_mean: fold(|o| o.od_share),
                availability_lo_mean: fold(|o| o.availability_lo),
                availability_hi_mean: fold(|o| o.availability_hi),
                optimism_gap_mean: {
                    let gaps: Vec<f64> = runs
                        .iter()
                        .flat_map(|o| o.optimism_gap.iter().map(|(_, g)| *g))
                        .collect();
                    if gaps.is_empty() {
                        None
                    } else {
                        Some(gaps.iter().sum::<f64>() / gaps.len() as f64)
                    }
                },
                migrations_total: runs.iter().map(|o| o.migrations).sum(),
            }
        })
        .collect()
}

fn run_to_json(o: &ScenarioOutcome) -> Json {
    let mut j = Json::obj();
    // Seeds are full-range u64; JSON numbers (f64) lose bits above 2^53,
    // so the seed travels as a string to stay replayable.
    j.set("replicate", Json::Num(o.replicate as f64))
        .set("run_seed", Json::Str(o.run_seed.to_string()))
        .set("jobs", Json::Num(o.jobs as f64))
        .set("alpha", Json::Num(o.average_unit_cost))
        .set("regret", Json::Num(o.average_regret))
        .set("regret_bound", Json::Num(o.regret_bound))
        .set("pool_utilization", Json::Num(o.pool_utilization))
        .set("so_share", Json::Num(o.so_share))
        .set("spot_share", Json::Num(o.spot_share))
        .set("od_share", Json::Num(o.od_share))
        .set("availability_lo", Json::Num(o.availability_lo))
        .set("availability_hi", Json::Num(o.availability_hi))
        .set("best_policy", Json::Str(o.best_policy.clone()));
    // Only routed multi-offer runs carry offer shares; omitting the key
    // otherwise keeps legacy rows byte-identical to the pre-MarketView
    // schema.
    if !o.offer_shares.is_empty() {
        let mut shares = Json::obj();
        for (label, share) in &o.offer_shares {
            shares.set(label, Json::Num(*share));
        }
        j.set("offer_shares", shares);
    }
    if !o.policy_costs.is_empty() {
        let mut costs = Json::obj();
        for (label, cost) in &o.policy_costs {
            costs.set(label, Json::Num(*cost));
        }
        j.set("policy_costs", costs);
    }
    // Regime tags: same off-disk-when-empty idiom, so untagged rows keep
    // the pre-robustness byte shape.
    if !o.tags.is_empty() {
        j.set(
            "tags",
            Json::Arr(o.tags.iter().map(|t| Json::Str(t.clone())).collect()),
        );
    }
    // Capacity-replay optimism gap: only capped worlds run the replay, so
    // only their rows carry the key (off-disk-when-empty, like the maps
    // above — capacity-free rows keep the legacy byte shape).
    if !o.optimism_gap.is_empty() {
        let mut gaps = Json::obj();
        for (label, gap) in &o.optimism_gap {
            gaps.set(label, Json::Num(*gap));
        }
        j.set("optimism_gap", gaps);
    }
    // Migration count: off-disk when zero, so migration-off rows are
    // byte-identical to the pre-migration schema.
    if o.migrations > 0 {
        j.set("migrations", Json::Num(o.migrations as f64));
    }
    j
}

/// Parse one detail row back into a [`ScenarioOutcome`]. Lossless for
/// every field the fleet merge and robustness scoring read: JSON numbers
/// serialize via shortest-round-trip `f64` formatting, so
/// `parse(serialize(o))` reproduces the exact bits. Map-backed fields
/// (`offer_shares`, `policy_costs`) come back in label order — the same
/// order serialization emits — so re-serializing a parsed row is
/// byte-identical to the original row.
pub fn outcome_from_json(scenario: &str, j: &Json) -> Result<ScenarioOutcome> {
    let field = |key: &str| -> Result<f64> {
        j.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("report row ('{scenario}'): missing number '{key}'"))
    };
    let pairs = |key: &str| -> Result<Vec<(String, f64)>> {
        match j.get(key) {
            None => Ok(Vec::new()),
            Some(Json::Obj(m)) => m
                .iter()
                .map(|(k, v)| {
                    v.as_f64()
                        .map(|x| (k.clone(), x))
                        .ok_or_else(|| anyhow!("report row ('{scenario}'): bad '{key}.{k}'"))
                })
                .collect(),
            Some(_) => Err(anyhow!("report row ('{scenario}'): '{key}' must be an object")),
        }
    };
    let run_seed = j
        .get("run_seed")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("report row ('{scenario}'): missing string 'run_seed'"))?
        .parse::<u64>()
        .map_err(|e| anyhow!("report row ('{scenario}'): bad run_seed: {e}"))?;
    Ok(ScenarioOutcome {
        scenario: scenario.to_string(),
        replicate: field("replicate")? as u64,
        run_seed,
        jobs: field("jobs")? as usize,
        average_unit_cost: field("alpha")?,
        average_regret: field("regret")?,
        regret_bound: field("regret_bound")?,
        pool_utilization: field("pool_utilization")?,
        so_share: field("so_share")?,
        spot_share: field("spot_share")?,
        od_share: field("od_share")?,
        availability_lo: field("availability_lo")?,
        availability_hi: field("availability_hi")?,
        best_policy: j
            .get("best_policy")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("report row ('{scenario}'): missing 'best_policy'"))?
            .to_string(),
        offer_shares: pairs("offer_shares")?,
        policy_costs: pairs("policy_costs")?,
        tags: match j.get("tags") {
            None => Vec::new(),
            Some(Json::Arr(arr)) => arr
                .iter()
                .map(|t| {
                    t.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| anyhow!("report row ('{scenario}'): tags must be strings"))
                })
                .collect::<Result<_>>()?,
            Some(_) => bail!("report row ('{scenario}'): 'tags' must be an array"),
        },
        optimism_gap: pairs("optimism_gap")?,
        migrations: j.opt_u64("migrations", 0),
    })
}

/// Batch-level metadata a `dagcloud.scenarios/v1` document records.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReportMeta {
    pub seeds: u64,
    pub base_seed: u64,
    pub smoke: bool,
}

/// Parse a whole `dagcloud.scenarios/v1` document back into its outcome
/// rows plus batch metadata — the inverse of [`report_json`] (aggregates
/// are recomputed, not parsed: they are a pure function of the rows).
pub fn outcomes_from_report(j: &Json) -> Result<(Vec<ScenarioOutcome>, ReportMeta)> {
    let schema = j.opt_str("schema", "");
    ensure!(
        schema == "dagcloud.scenarios/v1",
        "expected schema dagcloud.scenarios/v1, found '{schema}'"
    );
    let meta = ReportMeta {
        seeds: j
            .get("seeds")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("report: missing 'seeds'"))?,
        base_seed: j
            .get("base_seed")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("report: missing string 'base_seed'"))?
            .parse::<u64>()
            .map_err(|e| anyhow!("report: bad base_seed: {e}"))?,
        smoke: j
            .get("smoke")
            .and_then(Json::as_bool)
            .ok_or_else(|| anyhow!("report: missing 'smoke'"))?,
    };
    let mut out = Vec::new();
    let sections = j
        .get("scenarios")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("report: missing 'scenarios' array"))?;
    for s in sections {
        let name = s
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("report: scenario section missing 'name'"))?;
        let details = s
            .get("details")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("report ('{name}'): missing 'details' array"))?;
        for d in details {
            out.push(outcome_from_json(name, d)?);
        }
    }
    Ok((out, meta))
}

/// The per-scenario sections array (aggregate fields plus detail rows),
/// grouped in first-seen outcome order. Shared by [`report_json`] and the
/// fleet merge ([`crate::fleet::merge`]), which feeds it canonically
/// sorted outcomes so the sections are partition- and order-independent.
pub fn scenario_sections_json(outcomes: &[ScenarioOutcome]) -> Json {
    let aggs = aggregate(outcomes);
    Json::Arr(
        aggs.iter()
            .map(|a| {
                let mut sj = Json::obj();
                sj.set("name", Json::Str(a.scenario.clone()))
                    .set("runs", Json::Num(a.runs as f64))
                    .set("alpha_mean", Json::Num(a.alpha_mean))
                    .set("alpha_std", Json::Num(a.alpha_std))
                    .set("regret_mean", Json::Num(a.regret_mean))
                    .set("regret_bound_mean", Json::Num(a.regret_bound_mean))
                    .set(
                        "pool_utilization_mean",
                        Json::Num(a.pool_utilization_mean),
                    )
                    .set("so_share_mean", Json::Num(a.so_share_mean))
                    .set("spot_share_mean", Json::Num(a.spot_share_mean))
                    .set("od_share_mean", Json::Num(a.od_share_mean))
                    .set("availability_lo_mean", Json::Num(a.availability_lo_mean))
                    .set("availability_hi_mean", Json::Num(a.availability_hi_mean));
                if let Some(g) = a.optimism_gap_mean {
                    sj.set("optimism_gap_mean", Json::Num(g));
                }
                if a.migrations_total > 0 {
                    sj.set("migrations_total", Json::Num(a.migrations_total as f64));
                }
                sj.set(
                        "details",
                        Json::Arr(
                            outcomes
                                .iter()
                                .filter(|o| o.scenario == a.scenario)
                                .map(run_to_json)
                                .collect(),
                        ),
                    );
                sj
            })
            .collect(),
    )
}

/// The full report document.
pub fn report_json(outcomes: &[ScenarioOutcome], seeds: u64, base_seed: u64, smoke: bool) -> Json {
    let mut j = Json::obj();
    // base_seed is a full-range u64 like the per-run seeds: stringified so
    // the recorded value replays the batch exactly (f64 loses bits > 2^53).
    j.set("schema", Json::Str("dagcloud.scenarios/v1".into()))
        .set("seeds", Json::Num(seeds as f64))
        .set("base_seed", Json::Str(base_seed.to_string()))
        .set("smoke", Json::Bool(smoke))
        .set("scenarios", scenario_sections_json(outcomes));
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(name: &str, rep: u64, alpha: f64) -> ScenarioOutcome {
        ScenarioOutcome {
            scenario: name.into(),
            replicate: rep,
            run_seed: 100 + rep,
            jobs: 10,
            average_unit_cost: alpha,
            average_regret: 0.01,
            regret_bound: 0.5,
            pool_utilization: 0.0,
            so_share: 0.0,
            spot_share: 0.8,
            od_share: 0.2,
            availability_lo: 0.4,
            availability_hi: 0.9,
            best_policy: "proposed(β=1.000,β₀=-,b=0.24)".into(),
            offer_shares: Vec::new(),
            policy_costs: vec![
                ("proposed(β=1.000,β₀=-,b=0.24)".into(), alpha),
                ("proposed(β=0.769,β₀=-,b=0.18)".into(), alpha + 0.05),
            ],
            tags: Vec::new(),
            optimism_gap: Vec::new(),
            migrations: 0,
        }
    }

    #[test]
    fn optimism_gap_and_migrations_stay_off_disk_when_default() {
        // Capacity-free, migration-off rows keep the legacy byte shape.
        let plain = run_to_json(&outcome("a", 0, 0.2));
        assert!(plain.get("optimism_gap").is_none());
        assert!(plain.get("migrations").is_none());
        // Capped/migrating rows round-trip losslessly and re-serialize
        // byte-identically.
        let mut capped = outcome("b", 0, 0.3);
        capped.optimism_gap = vec![
            ("proposed(β=1.000,β₀=-,b=0.24)".into(), 0.0125),
            ("proposed(β=0.769,β₀=-,b=0.18)".into(), 0.0),
        ];
        capped.migrations = 3;
        let j = run_to_json(&capped);
        let back = outcome_from_json("b", &j).unwrap();
        assert_eq!(back.optimism_gap, capped.optimism_gap);
        assert_eq!(back.migrations, 3);
        assert_eq!(run_to_json(&back).pretty(), j.pretty());
        // Aggregates surface the mean gap / total migrations only when
        // some row carries them.
        let aggs = aggregate(&[outcome("a", 0, 0.2), capped.clone()]);
        assert_eq!(aggs[0].optimism_gap_mean, None);
        assert_eq!(aggs[0].migrations_total, 0);
        assert!((aggs[1].optimism_gap_mean.unwrap() - 0.00625).abs() < 1e-15);
        assert_eq!(aggs[1].migrations_total, 3);
        let doc = report_json(&[outcome("a", 0, 0.2), capped], 1, 7, false);
        let sections = doc.get("scenarios").unwrap().as_arr().unwrap();
        assert!(sections[0].get("optimism_gap_mean").is_none());
        assert!(sections[0].get("migrations_total").is_none());
        assert!(sections[1].get("optimism_gap_mean").is_some());
        assert_eq!(
            sections[1].get("migrations_total").unwrap().as_f64().unwrap(),
            3.0
        );
    }

    #[test]
    fn offer_shares_only_serialized_when_present() {
        let plain = run_to_json(&outcome("a", 0, 0.2));
        assert!(plain.get("offer_shares").is_none());
        let mut routed = outcome("b", 0, 0.3);
        routed.offer_shares = vec![("us-east/default".into(), 0.7), ("eu-west/default".into(), 0.3)];
        let j = run_to_json(&routed);
        let shares = j.get("offer_shares").unwrap();
        assert_eq!(shares.get("us-east/default").unwrap().as_f64().unwrap(), 0.7);
    }

    #[test]
    fn tags_only_serialized_when_present_and_roundtrip() {
        // Untagged rows keep the legacy byte shape.
        let plain = run_to_json(&outcome("a", 0, 0.2));
        assert!(plain.get("tags").is_none());
        // Tagged rows round-trip losslessly and re-serialize identically.
        let mut tagged = outcome("b", 0, 0.3);
        tagged.tags = vec!["calm".into(), "fault".into()];
        let j = run_to_json(&tagged);
        let back = outcome_from_json("b", &j).unwrap();
        assert_eq!(back.tags, tagged.tags);
        assert_eq!(run_to_json(&back).pretty(), j.pretty());
        // Malformed tags error instead of silently dropping.
        let mut bad = j.clone();
        bad.set("tags", Json::Num(1.0));
        assert!(outcome_from_json("b", &bad).is_err());
        let mut bad2 = j.clone();
        bad2.set("tags", Json::Arr(vec![Json::Num(1.0)]));
        assert!(outcome_from_json("b", &bad2).is_err());
    }

    #[test]
    fn aggregate_groups_and_averages() {
        let outs = vec![
            outcome("a", 0, 0.2),
            outcome("a", 1, 0.4),
            outcome("b", 0, 0.6),
        ];
        let aggs = aggregate(&outs);
        assert_eq!(aggs.len(), 2);
        assert_eq!(aggs[0].scenario, "a");
        assert_eq!(aggs[0].runs, 2);
        assert!((aggs[0].alpha_mean - 0.3).abs() < 1e-12);
        assert!(aggs[0].alpha_std > 0.0);
        assert_eq!(aggs[1].scenario, "b");
        assert_eq!(aggs[1].runs, 1);
    }

    #[test]
    fn detail_rows_roundtrip_losslessly() {
        let mut routed = outcome("w", 3, 0.123456789012345);
        routed.run_seed = u64::MAX - 7; // > 2^53: must survive via string
        routed.offer_shares =
            vec![("a/default".into(), 0.625), ("b/default".into(), 0.375)];
        let j = run_to_json(&routed);
        let back = outcome_from_json("w", &j).unwrap();
        // Bit-exact numeric fields and identical re-serialization.
        assert_eq!(back.run_seed, routed.run_seed);
        assert_eq!(back.average_unit_cost, routed.average_unit_cost);
        assert_eq!(back.policy_costs.len(), 2);
        assert_eq!(run_to_json(&back).pretty(), j.pretty());
        // Whole-document inverse.
        let outs = vec![outcome("a", 0, 0.2), outcome("b", 0, 0.3)];
        let doc = report_json(&outs, 1, 7, true);
        let (rows, meta) = outcomes_from_report(&doc).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(meta, ReportMeta { seeds: 1, base_seed: 7, smoke: true });
        assert_eq!(report_json(&rows, 1, 7, true).pretty(), doc.pretty());
        // Wrong schema is refused.
        let mut bad = doc.clone();
        bad.set("schema", Json::Str("dagcloud.fleet/v1".into()));
        assert!(outcomes_from_report(&bad).is_err());
    }

    #[test]
    fn report_is_deterministic_and_parses() {
        let outs = vec![outcome("a", 0, 0.2), outcome("b", 0, 0.3)];
        let a = report_json(&outs, 1, 7, true).pretty();
        let b = report_json(&outs, 1, 7, true).pretty();
        assert_eq!(a, b);
        let j = Json::parse(&a).unwrap();
        assert_eq!(
            j.get("schema").unwrap().as_str().unwrap(),
            "dagcloud.scenarios/v1"
        );
        let arr = j.get("scenarios").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(
            arr[0].get("details").unwrap().as_arr().unwrap().len(),
            1
        );
    }
}
