//! The built-in scenario registry: thirteen named worlds spanning the
//! market and workload regimes the platform must handle, from the paper's
//! §6.1 default to replayed real-format EC2 dumps (single- and
//! multi-series), multi-region arbitrage, the capacity-aware routed
//! markets, and a price-seesaw world where mid-window migration is
//! strictly profitable. `repro scenarios --list` prints the same
//! catalogue from the CLI.

use crate::market::SpotModel;
use crate::policy::routing::MigrationPolicy;
use crate::workload::MixComponent;

use super::spec::{
    InstanceTypeSpec, MarketSpec, PolicySetSpec, PriceSpec, RegionSpec, ReplayFormat, ReplaySpec,
    RoutingSpec, ScenarioSpec, WorkloadSpec,
};

/// The sample spot-price history shipped with the repo
/// (`examples/traces/spot_sample.csv`): ~120 time units of calm baseline
/// with two surge regimes, two-column `time,price` format. Embedded so the
/// registry works from any working directory; file-based replays use the
/// spec's `path` field.
pub const SAMPLE_TRACE_CSV: &str = include_str!("../../../examples/traces/spot_sample.csv");

/// A small `aws ec2 describe-spot-price-history` JSON-lines dump
/// (`examples/traces/ec2_sample.jsonl`): ~120 hours of m5.large/us-east-1a
/// history with a surge regime, deliberately containing out-of-order and
/// duplicate-timestamp records so the feed loaders' normalization is
/// exercised by the registry itself.
pub const EC2_SAMPLE_JSONL: &str = include_str!("../../../examples/traces/ec2_sample.jsonl");

/// The m5.large on-demand price the sample dump is normalized against.
pub const EC2_SAMPLE_OD_USD: f64 = 0.096;

/// A two-series `describe-spot-price-history` JSON-lines dump
/// (`examples/traces/ec2_multi.jsonl`): us-east-1a (calm with a surge
/// regime) and us-east-1b (steadier, pricier) m5.large histories
/// interleaved with deliberate disorder and duplicate timestamps. Loading
/// it without a series filter is an error naming both candidates — the
/// `ec2-az-select` world picks one with the spec-level `az` filter.
pub const EC2_MULTI_JSONL: &str = include_str!("../../../examples/traces/ec2_multi.jsonl");

fn base(name: &str, description: &str, model: SpotModel) -> ScenarioSpec {
    ScenarioSpec {
        name: name.into(),
        description: description.into(),
        market: MarketSpec::single(model, crate::market::ON_DEMAND_PRICE),
        workload: WorkloadSpec::uniform(2),
        pool_capacity: 0,
        policy_set: PolicySetSpec::Auto,
        jobs: 400,
        // Every builtin is at least a calm-regime world; worlds whose
        // price process visits a surge regime add "surge" below.
        tags: tags(&["calm"]),
        migration: MigrationPolicy::disabled(),
    }
}

fn tags(ts: &[&str]) -> Vec<String> {
    ts.iter().map(|t| t.to_string()).collect()
}

/// All built-in scenarios, in canonical order.
pub fn builtins() -> Vec<ScenarioSpec> {
    let calm = SpotModel::paper_default();
    let surge = SpotModel::BoundedExp {
        mean: 0.55,
        lo: 0.12,
        hi: 1.0,
    };

    let paper_default = base(
        "paper-default",
        "The §6.1 world: bounded-exp spot market, uniform type-2 jobs, no pool.",
        SpotModel::paper_default(),
    );

    let mut calm_surge = base(
        "calm-surge-markov",
        "Markov-modulated spot prices alternating calm and surge states \
         (price autocorrelation the i.i.d. §6.1 process lacks).",
        SpotModel::Markov {
            calm_mean: 0.13,
            surge_mean: 0.65,
            lo: 0.12,
            hi: 1.0,
            p_calm_to_surge: 0.04,
            p_surge_to_calm: 0.15,
        },
    );
    calm_surge.tags = tags(&["calm", "surge"]);

    let google = base(
        "google-fixed",
        "Google-style market: constant discounted price with exogenous \
         on/off availability; bids are irrelevant.",
        SpotModel::GoogleFixed {
            price: 0.3,
            availability: 0.7,
        },
    );

    let mut replayed = base(
        "replayed-trace",
        "CSV-replayed spot history (examples/traces/spot_sample.csv): calm \
         baseline with two surge regimes, tiled over the workload horizon.",
        SpotModel::paper_default(),
    );
    replayed.market.regions[0].price = PriceSpec::Replay(ReplaySpec::inline(SAMPLE_TRACE_CSV));
    replayed.tags = tags(&["calm", "surge"]);

    // A real-format EC2 dump streamed through the feed loaders: hourly
    // epoch timestamps scaled to one unit per hour, dollar prices
    // normalized by the on-demand list price.
    let mut ec2_replay = base(
        "ec2-feed-replay",
        "EC2 describe-spot-price-history JSON-lines dump \
         (examples/traces/ec2_sample.jsonl) streamed through the feed \
         loaders: out-of-order and duplicate records normalized, prices \
         scaled by the m5.large on-demand price.",
        SpotModel::paper_default(),
    );
    ec2_replay.market.regions[0].price = PriceSpec::Replay(ReplaySpec {
        csv: Some(EC2_SAMPLE_JSONL.to_string()),
        path: None,
        time_scale: 1.0 / 3600.0,
        price_scale: 1.0 / EC2_SAMPLE_OD_USD,
        tile: true,
        format: ReplayFormat::Ec2Json,
        normalize: false,
        az: None,
        instance_type: None,
    });
    ec2_replay.tags = tags(&["calm", "surge"]);

    // The per-series selection path: a dump carrying two availability-zone
    // series, restricted to one by the spec's `az` filter (without it the
    // loaders refuse, listing both candidates).
    let mut ec2_az_select = base(
        "ec2-az-select",
        "Two-series EC2 dump (examples/traces/ec2_multi.jsonl: us-east-1a \
         calm-with-surge + us-east-1b steady) restricted to us-east-1a by \
         the replay spec's az filter; prices scaled by the m5.large \
         on-demand price.",
        SpotModel::paper_default(),
    );
    ec2_az_select.market.regions[0].price = PriceSpec::Replay(ReplaySpec {
        csv: Some(EC2_MULTI_JSONL.to_string()),
        path: None,
        time_scale: 1.0 / 3600.0,
        price_scale: 1.0 / EC2_SAMPLE_OD_USD,
        tile: true,
        format: ReplayFormat::Ec2Json,
        normalize: false,
        az: Some("us-east-1a".into()),
        instance_type: Some("m5.large".into()),
    });
    ec2_az_select.tags = tags(&["calm", "surge"]);

    let multi_region = ScenarioSpec {
        name: "multi-region-arbitrage".into(),
        description: "Two regions with independent processes (one on a \
                      regime-switch schedule) and different on-demand \
                      prices, folded into the slot-wise cheapest composite."
            .into(),
        market: MarketSpec {
            regions: vec![
                RegionSpec {
                    name: "us-east".into(),
                    od_price: 1.0,
                    price: PriceSpec::Model(calm.clone()),
                    capacity: None,
                    instance_types: Vec::new(),
                },
                RegionSpec {
                    name: "eu-west".into(),
                    od_price: 1.15,
                    price: PriceSpec::Regimes(vec![(16.0, calm.clone()), (6.0, surge.clone())]),
                    capacity: None,
                    instance_types: Vec::new(),
                },
            ],
            routing: RoutingSpec::Arbitrage,
        },
        workload: WorkloadSpec::uniform(2),
        pool_capacity: 0,
        policy_set: PolicySetSpec::Auto,
        jobs: 400,
        tags: tags(&["calm", "surge"]),
        migration: MigrationPolicy::disabled(),
    };

    // A tightly-capped cheap primary region spilling into a pricier
    // overflow region: exercises capacity exhaustion end to end (tasks
    // that find both spot pools full degrade to on-demand).
    let capacity_crunch = ScenarioSpec {
        name: "capacity-crunch".into(),
        description: "Capacity-exhaustion world: a cheap primary region \
                      capped at 16 concurrent spot instances spills into a \
                      pricier overflow region (capped at 64); when both are \
                      full, tasks degrade to on-demand."
            .into(),
        market: MarketSpec {
            regions: vec![
                RegionSpec {
                    name: "primary".into(),
                    od_price: 1.0,
                    price: PriceSpec::Model(calm.clone()),
                    capacity: Some(16),
                    instance_types: Vec::new(),
                },
                RegionSpec {
                    name: "overflow".into(),
                    od_price: 1.2,
                    price: PriceSpec::Model(SpotModel::BoundedExp {
                        mean: 0.22,
                        lo: 0.15,
                        hi: 1.0,
                    }),
                    capacity: Some(64),
                    instance_types: Vec::new(),
                },
            ],
            routing: RoutingSpec::Spillover,
        },
        workload: WorkloadSpec::uniform(2),
        pool_capacity: 0,
        policy_set: PolicySetSpec::Auto,
        jobs: 400,
        tags: tags(&["calm"]),
        migration: MigrationPolicy::disabled(),
    };

    // Non-arbitrage routing across regions *and* instance types: every
    // task lands on the cheapest feasible offer and is charged that
    // offer's realized prices — no slot-wise composite anywhere.
    let multi_region_routed = ScenarioSpec {
        name: "multi-region-routed".into(),
        description: "Real routing world: 2 regions x 2 instance types \
                      with independent processes, different on-demand \
                      prices and a capped burst type; tasks route to the \
                      cheapest feasible offer instead of an arbitrage \
                      composite."
            .into(),
        market: MarketSpec {
            regions: vec![
                RegionSpec {
                    name: "us-east".into(),
                    od_price: 1.0,
                    price: PriceSpec::Model(calm.clone()),
                    capacity: Some(48),
                    instance_types: vec![InstanceTypeSpec {
                        name: "burst".into(),
                        od_price: Some(0.9),
                        price: PriceSpec::Model(SpotModel::Markov {
                            calm_mean: 0.14,
                            surge_mean: 0.7,
                            lo: 0.12,
                            hi: 1.0,
                            p_calm_to_surge: 0.05,
                            p_surge_to_calm: 0.2,
                        }),
                        capacity: Some(24),
                    }],
                },
                RegionSpec {
                    name: "eu-west".into(),
                    od_price: 1.15,
                    price: PriceSpec::Model(surge.clone()),
                    capacity: None,
                    instance_types: Vec::new(),
                },
            ],
            routing: RoutingSpec::Cheapest,
        },
        workload: WorkloadSpec::uniform(2),
        pool_capacity: 0,
        policy_set: PolicySetSpec::Auto,
        jobs: 400,
        tags: tags(&["calm", "surge"]),
        migration: MigrationPolicy::disabled(),
    };

    // A two-sided price seesaw built to make mid-window migration strictly
    // profitable: the regions alternate tight cheap and spike epochs in
    // opposite phase, so whichever offer a task starts on turns expensive
    // (above every §6.1 grid bid) mid-window while the other side turns
    // cheap. With migration on, in-flight tasks hop to the newly-cheap
    // side; with it off, they ride out the spike or degrade to on-demand.
    let cheap = SpotModel::BoundedExp {
        mean: 0.13,
        lo: 0.12,
        hi: 0.16,
    };
    let spike = SpotModel::BoundedExp {
        mean: 0.8,
        lo: 0.7,
        hi: 1.0,
    };
    let spot_spike_migration = ScenarioSpec {
        name: "spot-spike-migration".into(),
        description: "Opposite-phase price seesaw across two regions \
                      (tight cheap band vs spike band flipping every 3 \
                      units); mid-window migration to the newly-cheap side \
                      is strictly profitable, so this world pins the \
                      migration machinery end to end."
            .into(),
        market: MarketSpec {
            regions: vec![
                RegionSpec {
                    name: "east".into(),
                    od_price: 1.0,
                    price: PriceSpec::Regimes(vec![(3.0, cheap.clone()), (3.0, spike.clone())]),
                    capacity: None,
                    instance_types: Vec::new(),
                },
                RegionSpec {
                    name: "west".into(),
                    od_price: 1.0,
                    price: PriceSpec::Regimes(vec![(3.0, spike), (3.0, cheap)]),
                    capacity: None,
                    instance_types: Vec::new(),
                },
            ],
            routing: RoutingSpec::Cheapest,
        },
        workload: WorkloadSpec::uniform(2),
        pool_capacity: 0,
        policy_set: PolicySetSpec::Auto,
        jobs: 400,
        tags: tags(&["calm", "surge"]),
        migration: MigrationPolicy {
            switch_cost: 0.01,
            hysteresis_slots: 0,
        },
    };

    let mut bursty = base(
        "bursty-arrivals",
        "Cyclic load: long calm phases at a quarter of the base rate \
         punctuated by short 16x bursts.",
        SpotModel::paper_default(),
    );
    bursty.workload.rate_phases = vec![(6.0, 0.25), (2.0, 4.0)];

    let mut pool_heavy = base(
        "pool-heavy",
        "A large self-owned pool (rule 12 vs the market) over a mixed \
         type-2/type-3 workload; full 175-policy grid.",
        SpotModel::paper_default(),
    );
    pool_heavy.pool_capacity = 600;
    pool_heavy.policy_set = PolicySetSpec::Full;
    pool_heavy.workload.components = vec![
        MixComponent {
            job_type: 2,
            weight: 1.0,
        },
        MixComponent {
            job_type: 3,
            weight: 1.0,
        },
    ];

    let mut deadline_tight = base(
        "deadline-tight",
        "Deadline-pressure world: 3:1 mix of type-1 (x0 = 1.5) to type-2 \
         jobs — little slack for the allocation to exploit.",
        SpotModel::paper_default(),
    );
    deadline_tight.workload.components = vec![
        MixComponent {
            job_type: 1,
            weight: 3.0,
        },
        MixComponent {
            job_type: 2,
            weight: 1.0,
        },
    ];

    vec![
        paper_default,
        calm_surge,
        google,
        replayed,
        ec2_replay,
        ec2_az_select,
        multi_region,
        capacity_crunch,
        multi_region_routed,
        spot_spike_migration,
        bursty,
        pool_heavy,
        deadline_tight,
    ]
}

/// Canonical registry names.
pub fn builtin_names() -> Vec<String> {
    builtins().into_iter().map(|s| s.name).collect()
}

/// Look up one built-in scenario by name.
pub fn find(name: &str) -> Option<ScenarioSpec> {
    builtins().into_iter().find(|s| s.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_expected_worlds() {
        let names = builtin_names();
        assert_eq!(names.len(), 13);
        for want in [
            "paper-default",
            "calm-surge-markov",
            "google-fixed",
            "replayed-trace",
            "ec2-feed-replay",
            "ec2-az-select",
            "multi-region-arbitrage",
            "capacity-crunch",
            "multi-region-routed",
            "spot-spike-migration",
            "bursty-arrivals",
            "pool-heavy",
            "deadline-tight",
        ] {
            assert!(names.iter().any(|n| n == want), "missing '{want}'");
        }
        let mut dedup = names.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len(), "duplicate names");
    }

    #[test]
    fn routed_worlds_carry_capacity_and_routing() {
        let crunch = find("capacity-crunch").unwrap();
        assert_eq!(crunch.market.routing, RoutingSpec::Spillover);
        let offers = crunch.market.flattened_offers();
        assert_eq!(offers.len(), 2);
        assert_eq!(offers[0].capacity, Some(16));
        assert_eq!(offers[1].capacity, Some(64));

        let routed = find("multi-region-routed").unwrap();
        assert_eq!(routed.market.routing, RoutingSpec::Cheapest);
        let offers = routed.market.flattened_offers();
        assert_eq!(offers.len(), 3, "2 regions x (default + burst type)");
        assert_eq!(offers[1].instance_type, "burst");
        assert_eq!(offers[1].od_price, 0.9);
        assert!(offers[2].capacity.is_none());
    }

    #[test]
    fn migration_world_is_the_only_builtin_with_migration_on() {
        for s in builtins() {
            assert_eq!(
                s.migration.enabled(),
                s.name == "spot-spike-migration",
                "'{}'",
                s.name
            );
        }
        let m = find("spot-spike-migration").unwrap();
        assert_eq!(m.market.routing, RoutingSpec::Cheapest);
        assert_eq!(m.migration.switch_cost, 0.01);
        assert_eq!(m.migration.hysteresis_slots, 0);
        // Both sides are uncapped: the seesaw tests pure price-driven
        // migration, not capacity pressure.
        assert!(m.market.flattened_offers().iter().all(|o| o.capacity.is_none()));
        // The seesaw phases really oppose each other.
        match (&m.market.regions[0].price, &m.market.regions[1].price) {
            (PriceSpec::Regimes(a), PriceSpec::Regimes(b)) => {
                assert_eq!(a.len(), 2);
                assert_eq!(b.len(), 2);
                assert_eq!(a[0].1, b[1].1, "east's cheap epoch is west's second");
                assert_eq!(a[1].1, b[0].1, "east's spike epoch is west's first");
            }
            other => panic!("expected regime schedules, got {other:?}"),
        }
    }

    #[test]
    fn all_builtins_validate() {
        for s in builtins() {
            s.validate().unwrap_or_else(|e| panic!("{}: {e}", s.name));
        }
    }

    #[test]
    fn every_builtin_carries_regime_tags() {
        for s in builtins() {
            assert!(!s.tags.is_empty(), "'{}' has no regime tags", s.name);
            assert!(s.tags.contains(&"calm".to_string()), "'{}'", s.name);
        }
        // Worlds whose price process visits a surge regime are tagged so.
        for name in [
            "calm-surge-markov",
            "replayed-trace",
            "ec2-feed-replay",
            "ec2-az-select",
            "multi-region-arbitrage",
            "multi-region-routed",
            "spot-spike-migration",
        ] {
            let s = find(name).unwrap();
            assert!(s.tags.contains(&"surge".to_string()), "'{name}'");
        }
        // Single-regime worlds are calm-only.
        assert_eq!(find("paper-default").unwrap().tags, vec!["calm"]);
    }

    #[test]
    fn find_is_by_name() {
        assert!(find("pool-heavy").unwrap().pool_capacity > 0);
        assert!(find("nope").is_none());
    }

    #[test]
    fn ec2_replay_world_normalizes_the_dump() {
        let s = find("ec2-feed-replay").unwrap();
        match &s.market.regions[0].price {
            PriceSpec::Replay(r) => {
                assert_eq!(r.format, ReplayFormat::Ec2Json);
                assert!(!r.csv.as_deref().unwrap().contains("SpotPriceHistory"));
                assert!(r.csv.as_deref().unwrap().contains("\"SpotPrice\""));
            }
            other => panic!("expected replay price spec, got {other:?}"),
        }
        // The dump realizes into a normalized trace: ~120 units of
        // history, prices inside the scaled band, disorder absorbed.
        let trace = crate::scenario::runner::build_market(&s, 10.0, 1).unwrap().0;
        assert!(trace.horizon() > 100.0, "horizon {}", trace.horizon());
        let lo = (0..trace.num_slots())
            .map(|k| trace.price_of_slot(k))
            .fold(f64::INFINITY, f64::min);
        let hi = (0..trace.num_slots())
            .map(|k| trace.price_of_slot(k))
            .fold(0.0, f64::max);
        assert!(lo > 0.1 && lo < 0.2, "lo {lo}");
        assert!(hi > 0.5 && hi < 1.0, "hi {hi}");
    }

    #[test]
    fn az_select_world_filters_one_series_out_of_two() {
        let s = find("ec2-az-select").unwrap();
        match &s.market.regions[0].price {
            PriceSpec::Replay(r) => {
                assert_eq!(r.az.as_deref(), Some("us-east-1a"));
                assert_eq!(r.instance_type.as_deref(), Some("m5.large"));
                assert!(r.csv.as_deref().unwrap().contains("us-east-1b"));
            }
            other => panic!("expected replay price spec, got {other:?}"),
        }
        // With the filter the world realizes (1a band: calm ~0.2 with a
        // surge toward ~0.78 normalized)...
        let trace = crate::scenario::runner::build_market(&s, 10.0, 1).unwrap().0;
        assert!(trace.horizon() > 100.0, "horizon {}", trace.horizon());
        let lo = (0..trace.num_slots())
            .map(|k| trace.price_of_slot(k))
            .fold(f64::INFINITY, f64::min);
        let hi = (0..trace.num_slots())
            .map(|k| trace.price_of_slot(k))
            .fold(0.0, f64::max);
        assert!(lo > 0.1 && lo < 0.25, "lo {lo}");
        assert!(hi > 0.4 && hi < 0.9, "hi {hi}");
        // ...without it the loaders refuse, naming both series.
        let mut unfiltered = s.clone();
        if let PriceSpec::Replay(r) = &mut unfiltered.market.regions[0].price {
            r.az = None;
            r.instance_type = None;
        }
        let err = crate::scenario::runner::build_market(&unfiltered, 10.0, 1)
            .unwrap_err()
            .to_string();
        assert!(err.contains("us-east-1a") && err.contains("us-east-1b"), "{err}");
    }

    #[test]
    fn replayed_scenario_embeds_the_sample_trace() {
        let s = find("replayed-trace").unwrap();
        match &s.market.regions[0].price {
            PriceSpec::Replay(r) => {
                assert!(r.csv.as_deref().unwrap().contains("time,price"));
                assert!(r.tile);
            }
            other => panic!("expected replay price spec, got {other:?}"),
        }
    }
}
