//! The built-in scenario registry: ~8 named worlds spanning the market and
//! workload regimes the platform must handle, from the paper's §6.1 default
//! to replayed real-style traces and multi-region arbitrage.

use crate::market::SpotModel;
use crate::workload::MixComponent;

use super::spec::{
    MarketSpec, PolicySetSpec, PriceSpec, RegionSpec, ReplaySpec, ScenarioSpec, WorkloadSpec,
};

/// The sample spot-price history shipped with the repo
/// (`examples/traces/spot_sample.csv`): ~120 time units of calm baseline
/// with two surge regimes, two-column `time,price` format. Embedded so the
/// registry works from any working directory; file-based replays use the
/// spec's `path` field.
pub const SAMPLE_TRACE_CSV: &str = include_str!("../../../examples/traces/spot_sample.csv");

fn base(name: &str, description: &str, model: SpotModel) -> ScenarioSpec {
    ScenarioSpec {
        name: name.into(),
        description: description.into(),
        market: MarketSpec::single(model, crate::market::ON_DEMAND_PRICE),
        workload: WorkloadSpec::uniform(2),
        pool_capacity: 0,
        policy_set: PolicySetSpec::Auto,
        jobs: 400,
    }
}

/// All built-in scenarios, in canonical order.
pub fn builtins() -> Vec<ScenarioSpec> {
    let calm = SpotModel::paper_default();
    let surge = SpotModel::BoundedExp {
        mean: 0.55,
        lo: 0.12,
        hi: 1.0,
    };

    let paper_default = base(
        "paper-default",
        "The §6.1 world: bounded-exp spot market, uniform type-2 jobs, no pool.",
        SpotModel::paper_default(),
    );

    let calm_surge = base(
        "calm-surge-markov",
        "Markov-modulated spot prices alternating calm and surge states \
         (price autocorrelation the i.i.d. §6.1 process lacks).",
        SpotModel::Markov {
            calm_mean: 0.13,
            surge_mean: 0.65,
            lo: 0.12,
            hi: 1.0,
            p_calm_to_surge: 0.04,
            p_surge_to_calm: 0.15,
        },
    );

    let google = base(
        "google-fixed",
        "Google-style market: constant discounted price with exogenous \
         on/off availability; bids are irrelevant.",
        SpotModel::GoogleFixed {
            price: 0.3,
            availability: 0.7,
        },
    );

    let mut replayed = base(
        "replayed-trace",
        "CSV-replayed spot history (examples/traces/spot_sample.csv): calm \
         baseline with two surge regimes, tiled over the workload horizon.",
        SpotModel::paper_default(),
    );
    replayed.market.regions[0].price = PriceSpec::Replay(ReplaySpec::inline(SAMPLE_TRACE_CSV));

    let multi_region = ScenarioSpec {
        name: "multi-region-arbitrage".into(),
        description: "Two regions with independent processes (one on a \
                      regime-switch schedule) and different on-demand \
                      prices, folded into the slot-wise cheapest composite."
            .into(),
        market: MarketSpec {
            regions: vec![
                RegionSpec {
                    name: "us-east".into(),
                    od_price: 1.0,
                    price: PriceSpec::Model(calm.clone()),
                },
                RegionSpec {
                    name: "eu-west".into(),
                    od_price: 1.15,
                    price: PriceSpec::Regimes(vec![(16.0, calm.clone()), (6.0, surge.clone())]),
                },
            ],
            arbitrage: true,
        },
        workload: WorkloadSpec::uniform(2),
        pool_capacity: 0,
        policy_set: PolicySetSpec::Auto,
        jobs: 400,
    };

    let mut bursty = base(
        "bursty-arrivals",
        "Cyclic load: long calm phases at a quarter of the base rate \
         punctuated by short 16x bursts.",
        SpotModel::paper_default(),
    );
    bursty.workload.rate_phases = vec![(6.0, 0.25), (2.0, 4.0)];

    let mut pool_heavy = base(
        "pool-heavy",
        "A large self-owned pool (rule 12 vs the market) over a mixed \
         type-2/type-3 workload; full 175-policy grid.",
        SpotModel::paper_default(),
    );
    pool_heavy.pool_capacity = 600;
    pool_heavy.policy_set = PolicySetSpec::Full;
    pool_heavy.workload.components = vec![
        MixComponent {
            job_type: 2,
            weight: 1.0,
        },
        MixComponent {
            job_type: 3,
            weight: 1.0,
        },
    ];

    let mut deadline_tight = base(
        "deadline-tight",
        "Deadline-pressure world: 3:1 mix of type-1 (x0 = 1.5) to type-2 \
         jobs — little slack for the allocation to exploit.",
        SpotModel::paper_default(),
    );
    deadline_tight.workload.components = vec![
        MixComponent {
            job_type: 1,
            weight: 3.0,
        },
        MixComponent {
            job_type: 2,
            weight: 1.0,
        },
    ];

    vec![
        paper_default,
        calm_surge,
        google,
        replayed,
        multi_region,
        bursty,
        pool_heavy,
        deadline_tight,
    ]
}

/// Canonical registry names.
pub fn builtin_names() -> Vec<String> {
    builtins().into_iter().map(|s| s.name).collect()
}

/// Look up one built-in scenario by name.
pub fn find(name: &str) -> Option<ScenarioSpec> {
    builtins().into_iter().find(|s| s.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_expected_worlds() {
        let names = builtin_names();
        assert_eq!(names.len(), 8);
        for want in [
            "paper-default",
            "calm-surge-markov",
            "google-fixed",
            "replayed-trace",
            "multi-region-arbitrage",
            "bursty-arrivals",
            "pool-heavy",
            "deadline-tight",
        ] {
            assert!(names.iter().any(|n| n == want), "missing '{want}'");
        }
        let mut dedup = names.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len(), "duplicate names");
    }

    #[test]
    fn all_builtins_validate() {
        for s in builtins() {
            s.validate().unwrap_or_else(|e| panic!("{}: {e}", s.name));
        }
    }

    #[test]
    fn find_is_by_name() {
        assert!(find("pool-heavy").unwrap().pool_capacity > 0);
        assert!(find("nope").is_none());
    }

    #[test]
    fn replayed_scenario_embeds_the_sample_trace() {
        let s = find("replayed-trace").unwrap();
        match &s.market.regions[0].price {
            PriceSpec::Replay(r) => {
                assert!(r.csv.as_deref().unwrap().contains("time,price"));
                assert!(r.tile);
            }
            other => panic!("expected replay price spec, got {other:?}"),
        }
    }
}
