//! The declarative scenario specification.
//!
//! A [`ScenarioSpec`] composes a *world* the evaluation platform can run:
//!
//! * a **market** — one or more regions, each with its own on-demand price
//!   and price process (a [`SpotModel`], a cyclic regime-switch schedule,
//!   or a CSV-replayed real trace), optionally folded into an arbitrage
//!   composite;
//! * a **workload** — a weighted mix of §6.1 job types under a cyclic
//!   arrival-rate schedule;
//! * a **pool** — the self-owned capacity;
//! * a **policy set** — which grid the TOLA learner runs over.
//!
//! Specs round-trip through the crate's own JSON (`util::json`; serde is
//! unavailable offline), so worlds can live in files, be diffed, and be
//! shipped to sharded runners.

use anyhow::{bail, ensure, Result};

use crate::market::{spot_model_from_json, spot_model_to_json, SpotModel};
use crate::util::json::Json;
use crate::workload::MixComponent;

/// How a region's per-slot prices are produced.
#[derive(Debug, Clone, PartialEq)]
pub enum PriceSpec {
    /// A single synthetic price process.
    Model(SpotModel),
    /// Regime-switch schedule: `(duration, model)` segments cycled over the
    /// horizon (each segment's process keeps its RNG/Markov state across
    /// cycles).
    Regimes(Vec<(f64, SpotModel)>),
    /// A CSV-replayed real price history (see [`crate::market::replay`]).
    Replay(ReplaySpec),
}

/// A CSV replay source: inline content or a file path (exactly one).
#[derive(Debug, Clone, PartialEq)]
pub struct ReplaySpec {
    pub csv: Option<String>,
    pub path: Option<String>,
    /// Multiplies CSV timestamps into simulated time units.
    pub time_scale: f64,
    /// Multiplies CSV prices (normalize against the on-demand price).
    pub price_scale: f64,
    /// Tile the trace to cover the workload horizon (short histories wrap).
    pub tile: bool,
}

impl ReplaySpec {
    pub fn inline(csv: &str) -> ReplaySpec {
        ReplaySpec {
            csv: Some(csv.to_string()),
            path: None,
            time_scale: 1.0,
            price_scale: 1.0,
            tile: true,
        }
    }
}

/// One market region.
#[derive(Debug, Clone, PartialEq)]
pub struct RegionSpec {
    pub name: String,
    pub od_price: f64,
    pub price: PriceSpec,
}

/// The market side of a scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct MarketSpec {
    pub regions: Vec<RegionSpec>,
    /// Fold multiple regions into the slot-wise cheapest composite
    /// ([`crate::market::multi::arbitrage_composite`]). When false, region 0
    /// is the home region and the rest are ignored by the runner (reserved
    /// for a future multi-coordinator fleet).
    pub arbitrage: bool,
}

impl MarketSpec {
    /// A single-region market over one synthetic model.
    pub fn single(model: SpotModel, od_price: f64) -> MarketSpec {
        MarketSpec {
            regions: vec![RegionSpec {
                name: "default".into(),
                od_price,
                price: PriceSpec::Model(model),
            }],
            arbitrage: false,
        }
    }
}

/// The workload side of a scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSpec {
    /// Weighted job-type mix (at least one component).
    pub components: Vec<MixComponent>,
    /// Base Poisson arrival rate λ.
    pub arrival_rate: f64,
    /// Cyclic `(duration, rate multiplier)` phases; empty = constant rate.
    pub rate_phases: Vec<(f64, f64)>,
    /// Use the reduced task counts of [`crate::workload::GeneratorConfig::small`]
    /// (smoke runs / CI).
    pub small_tasks: bool,
}

impl WorkloadSpec {
    pub fn uniform(job_type: u8) -> WorkloadSpec {
        WorkloadSpec {
            components: vec![MixComponent {
                job_type,
                weight: 1.0,
            }],
            arrival_rate: 4.0,
            rate_phases: Vec::new(),
            small_tasks: false,
        }
    }
}

/// Which policy grid the learner runs over.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicySetSpec {
    /// `Full` when the scenario has a pool, else `SpotOnly`.
    Auto,
    /// The §6.1 set `P` without β₀ (25 policies).
    SpotOnly,
    /// The §6.1 set `P` with β₀ (175 policies).
    Full,
    /// The benchmark set `P'` (Even windows + naive self-owned, 5 bids).
    Benchmark,
}

impl PolicySetSpec {
    pub fn as_str(&self) -> &'static str {
        match self {
            PolicySetSpec::Auto => "auto",
            PolicySetSpec::SpotOnly => "spot_only",
            PolicySetSpec::Full => "full",
            PolicySetSpec::Benchmark => "benchmark",
        }
    }

    pub fn from_str(s: &str) -> Result<PolicySetSpec> {
        Ok(match s {
            "auto" => PolicySetSpec::Auto,
            "spot_only" => PolicySetSpec::SpotOnly,
            "full" => PolicySetSpec::Full,
            "benchmark" => PolicySetSpec::Benchmark,
            other => bail!("unknown policy set '{other}' (auto|spot_only|full|benchmark)"),
        })
    }
}

/// A complete, runnable world.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    pub name: String,
    pub description: String,
    pub market: MarketSpec,
    pub workload: WorkloadSpec,
    /// Self-owned pool capacity (0 = no pool).
    pub pool_capacity: u32,
    pub policy_set: PolicySetSpec,
    /// Jobs per run (the runner's `--jobs` / `--smoke` flags override).
    pub jobs: usize,
}

impl ScenarioSpec {
    /// Structural validation with path-style error messages.
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.name.is_empty(), "scenario: empty name");
        ensure!(self.jobs > 0, "scenario '{}': jobs must be positive", self.name);
        ensure!(
            !self.market.regions.is_empty(),
            "scenario '{}': market needs at least one region",
            self.name
        );
        for r in &self.market.regions {
            ensure!(
                r.od_price > 0.0,
                "scenario '{}', region '{}': od_price must be positive",
                self.name,
                r.name
            );
            match &r.price {
                PriceSpec::Model(m) => {
                    validate_spot_model(m, &self.name, &r.name)?;
                }
                PriceSpec::Regimes(segments) => {
                    ensure!(
                        !segments.is_empty(),
                        "scenario '{}', region '{}': empty regime schedule",
                        self.name,
                        r.name
                    );
                    ensure!(
                        segments.iter().all(|(d, _)| *d > 0.0),
                        "scenario '{}', region '{}': regime durations must be positive",
                        self.name,
                        r.name
                    );
                    for (_, m) in segments {
                        validate_spot_model(m, &self.name, &r.name)?;
                    }
                }
                PriceSpec::Replay(rp) => {
                    ensure!(
                        rp.csv.is_some() != rp.path.is_some(),
                        "scenario '{}', region '{}': replay needs exactly one of csv/path",
                        self.name,
                        r.name
                    );
                    ensure!(
                        rp.time_scale > 0.0 && rp.price_scale > 0.0,
                        "scenario '{}', region '{}': replay scales must be positive",
                        self.name,
                        r.name
                    );
                }
            }
        }
        ensure!(
            !self.workload.components.is_empty(),
            "scenario '{}': workload needs at least one component",
            self.name
        );
        for c in &self.workload.components {
            ensure!(
                (1..=4).contains(&c.job_type),
                "scenario '{}': job_type {} outside 1..=4",
                self.name,
                c.job_type
            );
            ensure!(
                c.weight >= 0.0,
                "scenario '{}': negative component weight",
                self.name
            );
        }
        ensure!(
            self.workload.components.iter().map(|c| c.weight).sum::<f64>() > 0.0,
            "scenario '{}': zero total component weight",
            self.name
        );
        ensure!(
            self.workload.arrival_rate > 0.0,
            "scenario '{}': arrival_rate must be positive",
            self.name
        );
        ensure!(
            self.workload.rate_phases.iter().all(|(d, m)| *d > 0.0 && *m > 0.0),
            "scenario '{}': rate phases need positive duration and multiplier",
            self.name
        );
        Ok(())
    }

    /// Parse a JSON document into a validated spec.
    pub fn parse(text: &str) -> Result<ScenarioSpec> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("scenario spec: {e}"))?;
        let spec = Self::from_json(&j)?;
        spec.validate()?;
        Ok(spec)
    }

    pub fn from_json(j: &Json) -> Result<ScenarioSpec> {
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("scenario: missing 'name'"))?
            .to_string();
        let description = j.opt_str("description", "").to_string();
        let market_j = j
            .get("market")
            .ok_or_else(|| anyhow::anyhow!("scenario '{name}': missing 'market'"))?;
        let workload_j = j
            .get("workload")
            .ok_or_else(|| anyhow::anyhow!("scenario '{name}': missing 'workload'"))?;
        let pool_capacity = j.opt_u64("pool_capacity", 0);
        ensure!(
            pool_capacity <= u32::MAX as u64,
            "scenario '{name}': pool_capacity {pool_capacity} exceeds u32"
        );
        Ok(ScenarioSpec {
            description,
            market: market_from_json(market_j, &name)?,
            workload: workload_from_json(workload_j, &name)?,
            pool_capacity: pool_capacity as u32,
            policy_set: PolicySetSpec::from_str(j.opt_str("policy_set", "auto"))?,
            jobs: j.opt_u64("jobs", 400) as usize,
            name,
        })
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", Json::Str(self.name.clone()))
            .set("description", Json::Str(self.description.clone()))
            .set("jobs", Json::Num(self.jobs as f64))
            .set("pool_capacity", Json::Num(self.pool_capacity as f64))
            .set("policy_set", Json::Str(self.policy_set.as_str().into()))
            .set("market", market_to_json(&self.market))
            .set("workload", workload_to_json(&self.workload));
        j
    }
}

/// Sanity-check a price process's parameters so a malformed spec fails
/// with a path-style error instead of a downstream panic (bounded-exp
/// rejection sampling asserts `lo < hi`) or a degenerate run.
fn validate_spot_model(m: &SpotModel, scenario: &str, region: &str) -> Result<()> {
    let ctx = || format!("scenario '{scenario}', region '{region}'");
    match m {
        SpotModel::BoundedExp { mean, lo, hi } => {
            ensure!(
                *mean > 0.0 && *lo >= 0.0 && lo < hi,
                "{}: bounded_exp needs mean > 0 and 0 <= lo < hi (mean={mean}, lo={lo}, hi={hi})",
                ctx()
            );
        }
        SpotModel::Markov {
            calm_mean,
            surge_mean,
            lo,
            hi,
            p_calm_to_surge,
            p_surge_to_calm,
        } => {
            ensure!(
                *calm_mean > 0.0 && *surge_mean > 0.0 && *lo >= 0.0 && lo < hi,
                "{}: markov needs positive means and 0 <= lo < hi",
                ctx()
            );
            ensure!(
                (0.0..=1.0).contains(p_calm_to_surge) && (0.0..=1.0).contains(p_surge_to_calm),
                "{}: markov transition probabilities must lie in [0, 1]",
                ctx()
            );
        }
        SpotModel::GoogleFixed {
            price,
            availability,
        } => {
            ensure!(
                *price > 0.0 && (0.0..=1.0).contains(availability),
                "{}: google needs price > 0 and availability in [0, 1]",
                ctx()
            );
        }
    }
    Ok(())
}

fn price_to_json(p: &PriceSpec) -> Json {
    let mut j = Json::obj();
    match p {
        PriceSpec::Model(m) => {
            j.set("kind", Json::Str("model".into()))
                .set("model", spot_model_to_json(m));
        }
        PriceSpec::Regimes(segments) => {
            j.set("kind", Json::Str("regimes".into())).set(
                "segments",
                Json::Arr(
                    segments
                        .iter()
                        .map(|(d, m)| {
                            let mut s = Json::obj();
                            s.set("duration", Json::Num(*d))
                                .set("model", spot_model_to_json(m));
                            s
                        })
                        .collect(),
                ),
            );
        }
        PriceSpec::Replay(r) => {
            j.set("kind", Json::Str("replay".into()))
                .set("time_scale", Json::Num(r.time_scale))
                .set("price_scale", Json::Num(r.price_scale))
                .set("tile", Json::Bool(r.tile));
            if let Some(csv) = &r.csv {
                j.set("csv", Json::Str(csv.clone()));
            }
            if let Some(path) = &r.path {
                j.set("path", Json::Str(path.clone()));
            }
        }
    }
    j
}

fn price_from_json(j: &Json, ctx: &str) -> Result<PriceSpec> {
    if let Some(k) = j.get("kind") {
        ensure!(
            matches!(k, Json::Str(_)),
            "{ctx}: price 'kind' must be a string"
        );
    }
    match j.opt_str("kind", "model") {
        "model" => {
            let m = j
                .get("model")
                .ok_or_else(|| anyhow::anyhow!("{ctx}: price kind 'model' missing 'model'"))?;
            Ok(PriceSpec::Model(spot_model_from_json(m)?))
        }
        "regimes" => {
            let segs = j
                .get("segments")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("{ctx}: price kind 'regimes' missing 'segments'"))?;
            let mut out = Vec::with_capacity(segs.len());
            for s in segs {
                let d = s.req_f64("duration")?;
                let m = s
                    .get("model")
                    .ok_or_else(|| anyhow::anyhow!("{ctx}: regime segment missing 'model'"))?;
                out.push((d, spot_model_from_json(m)?));
            }
            Ok(PriceSpec::Regimes(out))
        }
        "replay" => Ok(PriceSpec::Replay(ReplaySpec {
            csv: j.get("csv").and_then(Json::as_str).map(str::to_string),
            path: j.get("path").and_then(Json::as_str).map(str::to_string),
            time_scale: j.opt_f64("time_scale", 1.0),
            price_scale: j.opt_f64("price_scale", 1.0),
            tile: j.opt_bool("tile", true),
        })),
        other => bail!("{ctx}: unknown price kind '{other}' (model|regimes|replay)"),
    }
}

fn market_to_json(m: &MarketSpec) -> Json {
    let mut j = Json::obj();
    j.set("arbitrage", Json::Bool(m.arbitrage)).set(
        "regions",
        Json::Arr(
            m.regions
                .iter()
                .map(|r| {
                    let mut rj = Json::obj();
                    rj.set("name", Json::Str(r.name.clone()))
                        .set("od_price", Json::Num(r.od_price))
                        .set("price", price_to_json(&r.price));
                    rj
                })
                .collect(),
        ),
    );
    j
}

fn market_from_json(j: &Json, scenario: &str) -> Result<MarketSpec> {
    let regions_j = j
        .get("regions")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("scenario '{scenario}': market missing 'regions'"))?;
    let mut regions = Vec::with_capacity(regions_j.len());
    for (k, rj) in regions_j.iter().enumerate() {
        let name = rj.opt_str("name", "").to_string();
        let name = if name.is_empty() {
            format!("region-{k}")
        } else {
            name
        };
        let ctx = format!("scenario '{scenario}', region '{name}'");
        let price_j = rj
            .get("price")
            .ok_or_else(|| anyhow::anyhow!("{ctx}: missing 'price'"))?;
        regions.push(RegionSpec {
            od_price: rj.opt_f64("od_price", crate::market::ON_DEMAND_PRICE),
            price: price_from_json(price_j, &ctx)?,
            name,
        });
    }
    Ok(MarketSpec {
        regions,
        arbitrage: j.opt_bool("arbitrage", false),
    })
}

fn workload_to_json(w: &WorkloadSpec) -> Json {
    let mut j = Json::obj();
    j.set("arrival_rate", Json::Num(w.arrival_rate))
        .set("small_tasks", Json::Bool(w.small_tasks))
        .set(
            "components",
            Json::Arr(
                w.components
                    .iter()
                    .map(|c| {
                        let mut cj = Json::obj();
                        cj.set("job_type", Json::Num(c.job_type as f64))
                            .set("weight", Json::Num(c.weight));
                        cj
                    })
                    .collect(),
            ),
        )
        .set(
            "rate_phases",
            Json::Arr(
                w.rate_phases
                    .iter()
                    .map(|(d, m)| {
                        let mut pj = Json::obj();
                        pj.set("duration", Json::Num(*d))
                            .set("multiplier", Json::Num(*m));
                        pj
                    })
                    .collect(),
            ),
        );
    j
}

fn workload_from_json(j: &Json, scenario: &str) -> Result<WorkloadSpec> {
    let comps_j = j
        .get("components")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("scenario '{scenario}': workload missing 'components'"))?;
    let mut components = Vec::with_capacity(comps_j.len());
    for cj in comps_j {
        let job_type = cj.opt_u64("job_type", 2);
        ensure!(
            job_type <= u8::MAX as u64,
            "scenario '{scenario}': job_type {job_type} out of range"
        );
        components.push(MixComponent {
            job_type: job_type as u8,
            weight: cj.opt_f64("weight", 1.0),
        });
    }
    let mut rate_phases = Vec::new();
    if let Some(phases) = j.get("rate_phases").and_then(Json::as_arr) {
        for pj in phases {
            rate_phases.push((pj.req_f64("duration")?, pj.req_f64("multiplier")?));
        }
    }
    Ok(WorkloadSpec {
        components,
        arrival_rate: j.opt_f64("arrival_rate", 4.0),
        rate_phases,
        small_tasks: j.opt_bool("small_tasks", false),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ScenarioSpec {
        ScenarioSpec {
            name: "test-world".into(),
            description: "two regions, bursty".into(),
            market: MarketSpec {
                regions: vec![
                    RegionSpec {
                        name: "us-east".into(),
                        od_price: 1.0,
                        price: PriceSpec::Model(SpotModel::paper_default()),
                    },
                    RegionSpec {
                        name: "eu-west".into(),
                        od_price: 1.2,
                        price: PriceSpec::Regimes(vec![
                            (12.0, SpotModel::paper_default()),
                            (
                                4.0,
                                SpotModel::BoundedExp {
                                    mean: 0.5,
                                    lo: 0.12,
                                    hi: 1.0,
                                },
                            ),
                        ]),
                    },
                ],
                arbitrage: true,
            },
            workload: WorkloadSpec {
                components: vec![
                    MixComponent {
                        job_type: 1,
                        weight: 2.0,
                    },
                    MixComponent {
                        job_type: 3,
                        weight: 1.0,
                    },
                ],
                arrival_rate: 4.0,
                rate_phases: vec![(6.0, 0.25), (2.0, 4.0)],
                small_tasks: true,
            },
            pool_capacity: 120,
            policy_set: PolicySetSpec::Auto,
            jobs: 250,
        }
    }

    #[test]
    fn roundtrip_preserves_spec() {
        let s = sample();
        s.validate().unwrap();
        let j = s.to_json();
        let back = ScenarioSpec::from_json(&j).unwrap();
        assert_eq!(back, s);
        // And via text.
        let re = ScenarioSpec::parse(&j.pretty()).unwrap();
        assert_eq!(re, s);
    }

    #[test]
    fn replay_roundtrip() {
        let mut s = sample();
        s.market = MarketSpec {
            regions: vec![RegionSpec {
                name: "replayed".into(),
                od_price: 1.0,
                price: PriceSpec::Replay(ReplaySpec::inline("0,0.2\n5,0.5\n")),
            }],
            arbitrage: false,
        };
        s.validate().unwrap();
        let back = ScenarioSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn validation_catches_bad_specs() {
        let mut s = sample();
        s.workload.components.clear();
        assert!(s.validate().is_err());

        let mut s = sample();
        s.market.regions.clear();
        assert!(s.validate().is_err());

        let mut s = sample();
        s.workload.components[0].job_type = 9;
        assert!(s.validate().is_err());

        let mut s = sample();
        s.market.regions[0].price = PriceSpec::Replay(ReplaySpec {
            csv: None,
            path: None,
            time_scale: 1.0,
            price_scale: 1.0,
            tile: true,
        });
        assert!(s.validate().is_err());

        let mut s = sample();
        s.jobs = 0;
        assert!(s.validate().is_err());

        // Degenerate price-process parameters fail validation, not the run.
        let mut s = sample();
        s.market.regions[0].price = PriceSpec::Model(SpotModel::BoundedExp {
            mean: 0.13,
            lo: 1.0,
            hi: 0.5,
        });
        assert!(s.validate().is_err());

        let mut s = sample();
        s.market.regions[0].price = PriceSpec::Model(SpotModel::GoogleFixed {
            price: 0.3,
            availability: 1.5,
        });
        assert!(s.validate().is_err());
    }

    #[test]
    fn missing_required_keys_error() {
        assert!(ScenarioSpec::parse("{}").is_err());
        assert!(ScenarioSpec::parse(r#"{"name":"x"}"#).is_err());
        assert!(PolicySetSpec::from_str("bogus").is_err());
    }

    #[test]
    fn out_of_range_numbers_rejected_not_truncated() {
        let mut j = sample().to_json();
        j.set("pool_capacity", Json::Num(4294967296.0)); // 2^32
        assert!(ScenarioSpec::from_json(&j).is_err());

        let text = sample()
            .to_json()
            .pretty()
            .replace("\"job_type\": 1", "\"job_type\": 258");
        assert!(ScenarioSpec::parse(&text).is_err());
    }
}
