//! The declarative scenario specification.
//!
//! A [`ScenarioSpec`] composes a *world* the evaluation platform can run:
//!
//! * a **market** — one or more regions, each with its own on-demand
//!   price, per-slot spot capacity, and one or more instance types, each
//!   type with its own price process (a [`SpotModel`], a cyclic
//!   regime-switch schedule, or a CSV-replayed real trace); a routing mode
//!   says how the flattened `(region, instance_type)` offers combine —
//!   home-only, the arbitrage composite, or real capacity-aware routing;
//! * a **workload** — a weighted mix of §6.1 job types under a cyclic
//!   arrival-rate schedule;
//! * a **pool** — the self-owned capacity;
//! * a **policy set** — which grid the TOLA learner runs over.
//!
//! Specs round-trip through the crate's own JSON (`util::json`; serde is
//! unavailable offline), so worlds can live in files, be diffed, and be
//! shipped to sharded runners.

use anyhow::{bail, ensure, Result};

use crate::coordinator::config::{migration_from_json, migration_to_json};
use crate::market::{spot_model_from_json, spot_model_to_json, SpotModel};
use crate::policy::routing::MigrationPolicy;
use crate::util::json::Json;
use crate::workload::MixComponent;

/// How a region's per-slot prices are produced.
#[derive(Debug, Clone, PartialEq)]
pub enum PriceSpec {
    /// A single synthetic price process.
    Model(SpotModel),
    /// Regime-switch schedule: `(duration, model)` segments cycled over the
    /// horizon (each segment's process keeps its RNG/Markov state across
    /// cycles).
    Regimes(Vec<(f64, SpotModel)>),
    /// A CSV-replayed real price history (see [`crate::market::replay`]).
    Replay(ReplaySpec),
}

/// On-disk shape of a replayed price history.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReplayFormat {
    /// The repo's numeric `time,price` (or price-per-slot) CSV.
    #[default]
    Simple,
    /// `aws ec2 describe-spot-price-history` JSON / JSON-lines
    /// ([`crate::feed::FeedFormat::Ec2Json`]).
    Ec2Json,
    /// The region/AZ CSV dump shape with ISO-8601 timestamps
    /// ([`crate::feed::FeedFormat::Csv`]).
    Ec2Csv,
}

impl ReplayFormat {
    pub fn as_str(&self) -> &'static str {
        match self {
            ReplayFormat::Simple => "simple",
            ReplayFormat::Ec2Json => "ec2-json",
            ReplayFormat::Ec2Csv => "ec2-csv",
        }
    }

    pub fn from_str(s: &str) -> Result<ReplayFormat> {
        Ok(match s {
            "simple" => ReplayFormat::Simple,
            "ec2-json" => ReplayFormat::Ec2Json,
            "ec2-csv" => ReplayFormat::Ec2Csv,
            other => bail!("unknown replay format '{other}' (simple|ec2-json|ec2-csv)"),
        })
    }
}

/// A replayed price-history source: inline content or a file path
/// (exactly one; the `csv` field holds the inline text whatever the
/// format — the key predates the EC2 shapes).
#[derive(Debug, Clone, PartialEq)]
pub struct ReplaySpec {
    pub csv: Option<String>,
    pub path: Option<String>,
    /// Multiplies timestamps into simulated time units (EC2 formats yield
    /// epoch seconds, so e.g. `1/3600` makes a unit an hour).
    pub time_scale: f64,
    /// Multiplies prices (normalize against the on-demand price).
    pub price_scale: f64,
    /// Tile the trace to cover the workload horizon (short histories wrap).
    pub tile: bool,
    /// On-disk shape; EC2 formats always normalize record order.
    pub format: ReplayFormat,
    /// `simple` format only: sort-and-dedupe out-of-order timestamps
    /// instead of rejecting them (an explicit opt-in — see
    /// [`crate::market::replay::trace_from_csv_opts`]).
    pub normalize: bool,
    /// EC2 formats only: restrict a multi-series dump to one availability
    /// zone (the loaders refuse to silently interleave distinct series; a
    /// multi-series dump without a filter errors listing the candidates).
    pub az: Option<String>,
    /// EC2 formats only: restrict a multi-series dump to one instance type.
    pub instance_type: Option<String>,
}

impl ReplaySpec {
    pub fn inline(csv: &str) -> ReplaySpec {
        ReplaySpec {
            csv: Some(csv.to_string()),
            path: None,
            time_scale: 1.0,
            price_scale: 1.0,
            tile: true,
            format: ReplayFormat::Simple,
            normalize: false,
            az: None,
            instance_type: None,
        }
    }
}

/// An additional named instance type inside a region: its own price
/// process, optionally its own on-demand price (defaults to the region's)
/// and spot capacity.
#[derive(Debug, Clone, PartialEq)]
pub struct InstanceTypeSpec {
    pub name: String,
    /// `None`: inherit the region's `od_price`.
    pub od_price: Option<f64>,
    pub price: PriceSpec,
    /// Per-slot concurrent spot-instance cap; `None` = infinite.
    pub capacity: Option<u32>,
}

/// One market region. The region itself is its `default` instance-type
/// offer; `instance_types` adds further offers.
#[derive(Debug, Clone, PartialEq)]
pub struct RegionSpec {
    pub name: String,
    pub od_price: f64,
    pub price: PriceSpec,
    /// Per-slot concurrent spot-instance cap of the default offer;
    /// `None` = infinite (the paper's assumption).
    pub capacity: Option<u32>,
    /// Additional named instance types, each its own offer.
    pub instance_types: Vec<InstanceTypeSpec>,
}

/// How the market's flattened `(region, instance_type)` offers combine at
/// run time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutingSpec {
    /// Offer 0 is the home market; the rest are inert (the legacy
    /// single-trace behavior).
    #[default]
    Home,
    /// Fold every offer into the slot-wise cheapest composite
    /// ([`crate::market::MarketView::arbitrage_collapse`]) — free
    /// placement, requires every capacity to be infinite.
    Arbitrage,
    /// Route each task to the cheapest offer with remaining capacity
    /// ([`crate::policy::routing::RoutingPolicy::CheapestFeasible`]).
    Cheapest,
    /// Route each task to the first offer (declared order) with remaining
    /// capacity ([`crate::policy::routing::RoutingPolicy::Spillover`]).
    Spillover,
}

impl RoutingSpec {
    pub fn as_str(&self) -> &'static str {
        match self {
            RoutingSpec::Home => "home",
            RoutingSpec::Arbitrage => "arbitrage",
            RoutingSpec::Cheapest => "cheapest",
            RoutingSpec::Spillover => "spillover",
        }
    }

    pub fn from_str(s: &str) -> Result<RoutingSpec> {
        Ok(match s {
            "home" => RoutingSpec::Home,
            "arbitrage" => RoutingSpec::Arbitrage,
            "cheapest" => RoutingSpec::Cheapest,
            "spillover" => RoutingSpec::Spillover,
            other => bail!("unknown routing '{other}' (home|arbitrage|cheapest|spillover)"),
        })
    }

    /// The per-task runtime routing policy; `None` when the market
    /// collapses to a single composite offer before the run (arbitrage).
    pub fn runtime(&self) -> Option<crate::policy::routing::RoutingPolicy> {
        use crate::policy::routing::RoutingPolicy;
        match self {
            RoutingSpec::Home => Some(RoutingPolicy::Home),
            RoutingSpec::Arbitrage => None,
            RoutingSpec::Cheapest => Some(RoutingPolicy::CheapestFeasible),
            RoutingSpec::Spillover => Some(RoutingPolicy::Spillover),
        }
    }
}

/// One flattened `(region, instance_type)` offer of a market spec, in
/// canonical order (regions in declared order; within a region the
/// `default` offer first, then `instance_types` in declared order).
#[derive(Debug, Clone, PartialEq)]
pub struct FlatOffer {
    pub region: String,
    pub instance_type: String,
    pub od_price: f64,
    pub price: PriceSpec,
    pub capacity: Option<u32>,
}

/// The market side of a scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct MarketSpec {
    pub regions: Vec<RegionSpec>,
    /// How the flattened offers combine at run time.
    pub routing: RoutingSpec,
}

impl MarketSpec {
    /// A single-region market over one synthetic model.
    pub fn single(model: SpotModel, od_price: f64) -> MarketSpec {
        MarketSpec {
            regions: vec![RegionSpec {
                name: "default".into(),
                od_price,
                price: PriceSpec::Model(model),
                capacity: None,
                instance_types: Vec::new(),
            }],
            routing: RoutingSpec::Home,
        }
    }

    /// The flattened `(region, instance_type)` offer list in canonical
    /// order — what the runner realizes into a
    /// [`crate::market::MarketView`].
    pub fn flattened_offers(&self) -> Vec<FlatOffer> {
        let mut out = Vec::new();
        for r in &self.regions {
            out.push(FlatOffer {
                region: r.name.clone(),
                instance_type: "default".into(),
                od_price: r.od_price,
                price: r.price.clone(),
                capacity: r.capacity,
            });
            for it in &r.instance_types {
                out.push(FlatOffer {
                    region: r.name.clone(),
                    instance_type: it.name.clone(),
                    od_price: it.od_price.unwrap_or(r.od_price),
                    price: it.price.clone(),
                    capacity: it.capacity,
                });
            }
        }
        out
    }
}

/// The workload side of a scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSpec {
    /// Weighted job-type mix (at least one component).
    pub components: Vec<MixComponent>,
    /// Base Poisson arrival rate λ.
    pub arrival_rate: f64,
    /// Cyclic `(duration, rate multiplier)` phases; empty = constant rate.
    pub rate_phases: Vec<(f64, f64)>,
    /// Use the reduced task counts of [`crate::workload::GeneratorConfig::small`]
    /// (smoke runs / CI).
    pub small_tasks: bool,
}

impl WorkloadSpec {
    pub fn uniform(job_type: u8) -> WorkloadSpec {
        WorkloadSpec {
            components: vec![MixComponent {
                job_type,
                weight: 1.0,
            }],
            arrival_rate: 4.0,
            rate_phases: Vec::new(),
            small_tasks: false,
        }
    }
}

/// Which policy grid the learner runs over.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicySetSpec {
    /// `Full` when the scenario has a pool, else `SpotOnly`.
    Auto,
    /// The §6.1 set `P` without β₀ (25 policies).
    SpotOnly,
    /// The §6.1 set `P` with β₀ (175 policies).
    Full,
    /// The benchmark set `P'` (Even windows + naive self-owned, 5 bids).
    Benchmark,
}

impl PolicySetSpec {
    pub fn as_str(&self) -> &'static str {
        match self {
            PolicySetSpec::Auto => "auto",
            PolicySetSpec::SpotOnly => "spot_only",
            PolicySetSpec::Full => "full",
            PolicySetSpec::Benchmark => "benchmark",
        }
    }

    pub fn from_str(s: &str) -> Result<PolicySetSpec> {
        Ok(match s {
            "auto" => PolicySetSpec::Auto,
            "spot_only" => PolicySetSpec::SpotOnly,
            "full" => PolicySetSpec::Full,
            "benchmark" => PolicySetSpec::Benchmark,
            other => bail!("unknown policy set '{other}' (auto|spot_only|full|benchmark)"),
        })
    }
}

/// A complete, runnable world.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    pub name: String,
    pub description: String,
    pub market: MarketSpec,
    pub workload: WorkloadSpec,
    /// Self-owned pool capacity (0 = no pool).
    pub pool_capacity: u32,
    pub policy_set: PolicySetSpec,
    /// Jobs per run (the runner's `--jobs` / `--smoke` flags override).
    pub jobs: usize,
    /// Regime tags (e.g. `calm`, `surge`, `fault`) grouping worlds for the
    /// cross-regime promotion gate ([`crate::robustness`]). Empty = untagged;
    /// the empty default stays off-disk so pre-existing spec files
    /// round-trip byte-identically.
    pub tags: Vec<String>,
    /// Mid-window migration policy. The disabled default stays off-disk
    /// (like `tags`), so migration-free spec files round-trip
    /// byte-identically and run the exact pinned-offer executor path.
    pub migration: MigrationPolicy,
}

impl ScenarioSpec {
    /// Structural validation with path-style error messages.
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.name.is_empty(), "scenario: empty name");
        ensure!(self.jobs > 0, "scenario '{}': jobs must be positive", self.name);
        ensure!(
            !self.market.regions.is_empty(),
            "scenario '{}': market needs at least one region",
            self.name
        );
        for (ri, r) in self.market.regions.iter().enumerate() {
            ensure!(
                !self.market.regions[..ri].iter().any(|o| o.name == r.name),
                "scenario '{}': duplicate region name '{}'",
                self.name,
                r.name
            );
            ensure!(
                r.od_price > 0.0,
                "scenario '{}', region '{}': od_price must be positive",
                self.name,
                r.name
            );
            ensure!(
                r.capacity != Some(0),
                "scenario '{}', region '{}': capacity 0 is never placeable (omit it for infinite)",
                self.name,
                r.name
            );
            validate_price(&r.price, &self.name, &r.name)?;
            for (ti, it) in r.instance_types.iter().enumerate() {
                let ctx = format!("{}:{}", r.name, it.name);
                ensure!(
                    !it.name.is_empty() && it.name != "default",
                    "scenario '{}', region '{}': instance type names must be non-empty and \
                     not 'default' (the region itself is the default offer)",
                    self.name,
                    r.name
                );
                ensure!(
                    !r.instance_types[..ti].iter().any(|o| o.name == it.name),
                    "scenario '{}', region '{}': duplicate instance type '{}'",
                    self.name,
                    r.name,
                    it.name
                );
                if let Some(od) = it.od_price {
                    ensure!(
                        od > 0.0,
                        "scenario '{}', offer '{ctx}': od_price must be positive",
                        self.name
                    );
                }
                ensure!(
                    it.capacity != Some(0),
                    "scenario '{}', offer '{ctx}': capacity 0 is never placeable (omit it for infinite)",
                    self.name
                );
                validate_price(&it.price, &self.name, &ctx)?;
            }
        }
        if self.market.routing == RoutingSpec::Arbitrage {
            // The composite models free placement; a finite cap contradicts
            // it. Refuse here instead of silently ignoring the cap.
            for o in self.market.flattened_offers() {
                ensure!(
                    o.capacity.is_none(),
                    "scenario '{}': arbitrage routing assumes infinite capacity, but offer \
                     '{}/{}' is capped at {} (use cheapest or spillover routing)",
                    self.name,
                    o.region,
                    o.instance_type,
                    o.capacity.unwrap()
                );
            }
        }
        ensure!(
            !self.workload.components.is_empty(),
            "scenario '{}': workload needs at least one component",
            self.name
        );
        for c in &self.workload.components {
            ensure!(
                (1..=4).contains(&c.job_type),
                "scenario '{}': job_type {} outside 1..=4",
                self.name,
                c.job_type
            );
            ensure!(
                c.weight >= 0.0,
                "scenario '{}': negative component weight",
                self.name
            );
        }
        ensure!(
            self.workload.components.iter().map(|c| c.weight).sum::<f64>() > 0.0,
            "scenario '{}': zero total component weight",
            self.name
        );
        ensure!(
            self.workload.arrival_rate > 0.0,
            "scenario '{}': arrival_rate must be positive",
            self.name
        );
        ensure!(
            self.workload.rate_phases.iter().all(|(d, m)| *d > 0.0 && *m > 0.0),
            "scenario '{}': rate phases need positive duration and multiplier",
            self.name
        );
        for (ti, t) in self.tags.iter().enumerate() {
            ensure!(!t.is_empty(), "scenario '{}': empty regime tag", self.name);
            ensure!(
                !self.tags[..ti].contains(t),
                "scenario '{}': duplicate regime tag '{t}'",
                self.name
            );
        }
        self.migration
            .validate()
            .map_err(|e| anyhow::anyhow!("scenario '{}': migration: {e}", self.name))?;
        // Mirror the config dead-weight guard: a task pinned by Home
        // routing (or placed on the arbitrage composite) can never migrate.
        ensure!(
            !self.migration.enabled()
                || matches!(
                    self.market.routing,
                    RoutingSpec::Cheapest | RoutingSpec::Spillover
                ),
            "scenario '{}': migration requires cheapest|spillover routing",
            self.name
        );
        Ok(())
    }

    /// Parse a JSON document into a validated spec.
    pub fn parse(text: &str) -> Result<ScenarioSpec> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("scenario spec: {e}"))?;
        let spec = Self::from_json(&j)?;
        spec.validate()?;
        Ok(spec)
    }

    pub fn from_json(j: &Json) -> Result<ScenarioSpec> {
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("scenario: missing 'name'"))?
            .to_string();
        let description = j.opt_str("description", "").to_string();
        let market_j = j
            .get("market")
            .ok_or_else(|| anyhow::anyhow!("scenario '{name}': missing 'market'"))?;
        let workload_j = j
            .get("workload")
            .ok_or_else(|| anyhow::anyhow!("scenario '{name}': missing 'workload'"))?;
        let pool_capacity = j.opt_u64("pool_capacity", 0);
        ensure!(
            pool_capacity <= u32::MAX as u64,
            "scenario '{name}': pool_capacity {pool_capacity} exceeds u32"
        );
        let mut tags = Vec::new();
        if let Some(arr) = j.get("tags") {
            let arr = arr
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("scenario '{name}': 'tags' must be an array"))?;
            for t in arr {
                let t = t
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("scenario '{name}': tags must be strings"))?;
                tags.push(t.to_string());
            }
        }
        let migration = migration_from_json(j, &format!("scenario '{name}'"))?;
        Ok(ScenarioSpec {
            description,
            market: market_from_json(market_j, &name)?,
            workload: workload_from_json(workload_j, &name)?,
            pool_capacity: pool_capacity as u32,
            policy_set: PolicySetSpec::from_str(j.opt_str("policy_set", "auto"))?,
            jobs: j.opt_u64("jobs", 400) as usize,
            tags,
            migration,
            name,
        })
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", Json::Str(self.name.clone()))
            .set("description", Json::Str(self.description.clone()))
            .set("jobs", Json::Num(self.jobs as f64))
            .set("pool_capacity", Json::Num(self.pool_capacity as f64))
            .set("policy_set", Json::Str(self.policy_set.as_str().into()));
        // The empty default stays off-disk (pre-tag spec files round-trip
        // byte-identically).
        if !self.tags.is_empty() {
            j.set(
                "tags",
                Json::Arr(self.tags.iter().map(|t| Json::Str(t.clone())).collect()),
            );
        }
        // Disabled migration stays off-disk, like empty tags.
        if self.migration.enabled() {
            j.set("migration", migration_to_json(&self.migration));
        }
        j.set("market", market_to_json(&self.market))
            .set("workload", workload_to_json(&self.workload));
        j
    }
}

/// Sanity-check a price spec so a malformed world fails with a path-style
/// error instead of a downstream panic (bounded-exp rejection sampling
/// asserts `lo < hi`) or a degenerate run. Model parameter checks live on
/// [`SpotModel::validate`]; this adds the spec-level structure and the
/// `scenario, offer` context path.
fn validate_price(price: &PriceSpec, scenario: &str, offer: &str) -> Result<()> {
    let ctx = || format!("scenario '{scenario}', region '{offer}'");
    match price {
        PriceSpec::Model(m) => {
            m.validate().map_err(|e| anyhow::anyhow!("{}: {e}", ctx()))?;
        }
        PriceSpec::Regimes(segments) => {
            ensure!(!segments.is_empty(), "{}: empty regime schedule", ctx());
            ensure!(
                segments.iter().all(|(d, _)| *d > 0.0),
                "{}: regime durations must be positive",
                ctx()
            );
            for (_, m) in segments {
                m.validate().map_err(|e| anyhow::anyhow!("{}: {e}", ctx()))?;
            }
        }
        PriceSpec::Replay(rp) => {
            ensure!(
                rp.csv.is_some() != rp.path.is_some(),
                "{}: replay needs exactly one of csv/path",
                ctx()
            );
            ensure!(
                rp.time_scale > 0.0 && rp.price_scale > 0.0,
                "{}: replay scales must be positive",
                ctx()
            );
            ensure!(
                !(rp.normalize && rp.format != ReplayFormat::Simple),
                "{}: 'normalize' applies to the simple format only \
                 (the EC2 loaders always normalize record order)",
                ctx()
            );
            ensure!(
                !(rp.format == ReplayFormat::Simple
                    && (rp.az.is_some() || rp.instance_type.is_some())),
                "{}: 'az'/'instance_type' filters apply to the EC2 formats only \
                 (the simple time,price shape carries no series labels)",
                ctx()
            );
        }
    }
    Ok(())
}

fn price_to_json(p: &PriceSpec) -> Json {
    let mut j = Json::obj();
    match p {
        PriceSpec::Model(m) => {
            j.set("kind", Json::Str("model".into()))
                .set("model", spot_model_to_json(m));
        }
        PriceSpec::Regimes(segments) => {
            j.set("kind", Json::Str("regimes".into())).set(
                "segments",
                Json::Arr(
                    segments
                        .iter()
                        .map(|(d, m)| {
                            let mut s = Json::obj();
                            s.set("duration", Json::Num(*d))
                                .set("model", spot_model_to_json(m));
                            s
                        })
                        .collect(),
                ),
            );
        }
        PriceSpec::Replay(r) => {
            j.set("kind", Json::Str("replay".into()))
                .set("time_scale", Json::Num(r.time_scale))
                .set("price_scale", Json::Num(r.price_scale))
                .set("tile", Json::Bool(r.tile));
            // Defaults stay off-disk so pre-existing spec files round-trip
            // byte-identically.
            if r.format != ReplayFormat::Simple {
                j.set("format", Json::Str(r.format.as_str().into()));
            }
            if r.normalize {
                j.set("normalize", Json::Bool(true));
            }
            if let Some(az) = &r.az {
                j.set("az", Json::Str(az.clone()));
            }
            if let Some(it) = &r.instance_type {
                j.set("instance_type", Json::Str(it.clone()));
            }
            if let Some(csv) = &r.csv {
                j.set("csv", Json::Str(csv.clone()));
            }
            if let Some(path) = &r.path {
                j.set("path", Json::Str(path.clone()));
            }
        }
    }
    j
}

fn price_from_json(j: &Json, ctx: &str) -> Result<PriceSpec> {
    if let Some(k) = j.get("kind") {
        ensure!(
            matches!(k, Json::Str(_)),
            "{ctx}: price 'kind' must be a string"
        );
    }
    match j.opt_str("kind", "model") {
        "model" => {
            let m = j
                .get("model")
                .ok_or_else(|| anyhow::anyhow!("{ctx}: price kind 'model' missing 'model'"))?;
            Ok(PriceSpec::Model(spot_model_from_json(m)?))
        }
        "regimes" => {
            let segs = j
                .get("segments")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("{ctx}: price kind 'regimes' missing 'segments'"))?;
            let mut out = Vec::with_capacity(segs.len());
            for s in segs {
                let d = s.req_f64("duration")?;
                let m = s
                    .get("model")
                    .ok_or_else(|| anyhow::anyhow!("{ctx}: regime segment missing 'model'"))?;
                out.push((d, spot_model_from_json(m)?));
            }
            Ok(PriceSpec::Regimes(out))
        }
        "replay" => Ok(PriceSpec::Replay(ReplaySpec {
            csv: j.get("csv").and_then(Json::as_str).map(str::to_string),
            path: j.get("path").and_then(Json::as_str).map(str::to_string),
            time_scale: j.opt_f64("time_scale", 1.0),
            price_scale: j.opt_f64("price_scale", 1.0),
            tile: j.opt_bool("tile", true),
            format: ReplayFormat::from_str(j.opt_str("format", "simple"))
                .map_err(|e| anyhow::anyhow!("{ctx}: {e}"))?,
            normalize: j.opt_bool("normalize", false),
            az: j.get("az").and_then(Json::as_str).map(str::to_string),
            instance_type: j
                .get("instance_type")
                .and_then(Json::as_str)
                .map(str::to_string),
        })),
        other => bail!("{ctx}: unknown price kind '{other}' (model|regimes|replay)"),
    }
}

fn market_to_json(m: &MarketSpec) -> Json {
    let mut j = Json::obj();
    // `arbitrage` is kept alongside `routing` for readers of the old
    // one-bit schema; `from_json` checks the two agree.
    j.set("routing", Json::Str(m.routing.as_str().into()))
        .set(
            "arbitrage",
            Json::Bool(m.routing == RoutingSpec::Arbitrage),
        )
        .set(
            "regions",
            Json::Arr(
                m.regions
                    .iter()
                    .map(|r| {
                        let mut rj = Json::obj();
                        rj.set("name", Json::Str(r.name.clone()))
                            .set("od_price", Json::Num(r.od_price))
                            .set("price", price_to_json(&r.price));
                        if let Some(c) = r.capacity {
                            rj.set("capacity", Json::Num(c as f64));
                        }
                        if !r.instance_types.is_empty() {
                            rj.set(
                                "instance_types",
                                Json::Arr(
                                    r.instance_types
                                        .iter()
                                        .map(|it| {
                                            let mut ij = Json::obj();
                                            ij.set("name", Json::Str(it.name.clone()))
                                                .set("price", price_to_json(&it.price));
                                            if let Some(od) = it.od_price {
                                                ij.set("od_price", Json::Num(od));
                                            }
                                            if let Some(c) = it.capacity {
                                                ij.set("capacity", Json::Num(c as f64));
                                            }
                                            ij
                                        })
                                        .collect(),
                                ),
                            );
                        }
                        rj
                    })
                    .collect(),
            ),
        );
    j
}

fn market_from_json(j: &Json, scenario: &str) -> Result<MarketSpec> {
    let regions_j = j
        .get("regions")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("scenario '{scenario}': market missing 'regions'"))?;
    let mut regions = Vec::with_capacity(regions_j.len());
    for (k, rj) in regions_j.iter().enumerate() {
        let name = rj.opt_str("name", "").to_string();
        let name = if name.is_empty() {
            format!("region-{k}")
        } else {
            name
        };
        let ctx = format!("scenario '{scenario}', region '{name}'");
        let price_j = rj
            .get("price")
            .ok_or_else(|| anyhow::anyhow!("{ctx}: missing 'price'"))?;
        let mut instance_types = Vec::new();
        if let Some(arr) = rj.get("instance_types").and_then(Json::as_arr) {
            for ij in arr {
                let it_name = ij
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("{ctx}: instance type missing 'name'"))?
                    .to_string();
                let it_ctx = format!("{ctx}, instance type '{it_name}'");
                let it_price = ij
                    .get("price")
                    .ok_or_else(|| anyhow::anyhow!("{it_ctx}: missing 'price'"))?;
                // od_price is optional (inherit the region's) but a
                // present-and-malformed value must error, not silently
                // fall back to inheritance.
                let it_od = match ij.get("od_price") {
                    None => None,
                    Some(v) => Some(v.as_f64().ok_or_else(|| {
                        anyhow::anyhow!("{it_ctx}: od_price must be a number")
                    })?),
                };
                instance_types.push(InstanceTypeSpec {
                    od_price: it_od,
                    price: price_from_json(it_price, &it_ctx)?,
                    capacity: crate::market::view::capacity_from_json(ij, "capacity", &it_ctx)?,
                    name: it_name,
                });
            }
        }
        regions.push(RegionSpec {
            od_price: rj.opt_f64("od_price", crate::market::ON_DEMAND_PRICE),
            price: price_from_json(price_j, &ctx)?,
            capacity: crate::market::view::capacity_from_json(rj, "capacity", &ctx)?,
            instance_types,
            name,
        });
    }
    let routing = match (j.get("routing"), j.get("arbitrage")) {
        (Some(Json::Str(s)), arb) => {
            let routing = RoutingSpec::from_str(s)?;
            if let Some(a) = arb.and_then(Json::as_bool) {
                ensure!(
                    a == (routing == RoutingSpec::Arbitrage),
                    "scenario '{scenario}': market has routing '{}' but arbitrage={a} \
                     (drop one of the two keys)",
                    routing.as_str()
                );
            }
            routing
        }
        (Some(_), _) => bail!("scenario '{scenario}': market 'routing' must be a string"),
        (None, _) => {
            if j.opt_bool("arbitrage", false) {
                RoutingSpec::Arbitrage
            } else {
                RoutingSpec::Home
            }
        }
    };
    Ok(MarketSpec { regions, routing })
}

fn workload_to_json(w: &WorkloadSpec) -> Json {
    let mut j = Json::obj();
    j.set("arrival_rate", Json::Num(w.arrival_rate))
        .set("small_tasks", Json::Bool(w.small_tasks))
        .set(
            "components",
            Json::Arr(
                w.components
                    .iter()
                    .map(|c| {
                        let mut cj = Json::obj();
                        cj.set("job_type", Json::Num(c.job_type as f64))
                            .set("weight", Json::Num(c.weight));
                        cj
                    })
                    .collect(),
            ),
        )
        .set(
            "rate_phases",
            Json::Arr(
                w.rate_phases
                    .iter()
                    .map(|(d, m)| {
                        let mut pj = Json::obj();
                        pj.set("duration", Json::Num(*d))
                            .set("multiplier", Json::Num(*m));
                        pj
                    })
                    .collect(),
            ),
        );
    j
}

fn workload_from_json(j: &Json, scenario: &str) -> Result<WorkloadSpec> {
    let comps_j = j
        .get("components")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("scenario '{scenario}': workload missing 'components'"))?;
    let mut components = Vec::with_capacity(comps_j.len());
    for cj in comps_j {
        let job_type = cj.opt_u64("job_type", 2);
        ensure!(
            job_type <= u8::MAX as u64,
            "scenario '{scenario}': job_type {job_type} out of range"
        );
        components.push(MixComponent {
            job_type: job_type as u8,
            weight: cj.opt_f64("weight", 1.0),
        });
    }
    let mut rate_phases = Vec::new();
    if let Some(phases) = j.get("rate_phases").and_then(Json::as_arr) {
        for pj in phases {
            rate_phases.push((pj.req_f64("duration")?, pj.req_f64("multiplier")?));
        }
    }
    Ok(WorkloadSpec {
        components,
        arrival_rate: j.opt_f64("arrival_rate", 4.0),
        rate_phases,
        small_tasks: j.opt_bool("small_tasks", false),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ScenarioSpec {
        ScenarioSpec {
            name: "test-world".into(),
            description: "two regions, bursty".into(),
            market: MarketSpec {
                regions: vec![
                    RegionSpec {
                        name: "us-east".into(),
                        od_price: 1.0,
                        price: PriceSpec::Model(SpotModel::paper_default()),
                        capacity: None,
                        instance_types: Vec::new(),
                    },
                    RegionSpec {
                        name: "eu-west".into(),
                        od_price: 1.2,
                        price: PriceSpec::Regimes(vec![
                            (12.0, SpotModel::paper_default()),
                            (
                                4.0,
                                SpotModel::BoundedExp {
                                    mean: 0.5,
                                    lo: 0.12,
                                    hi: 1.0,
                                },
                            ),
                        ]),
                        capacity: None,
                        instance_types: Vec::new(),
                    },
                ],
                routing: RoutingSpec::Arbitrage,
            },
            workload: WorkloadSpec {
                components: vec![
                    MixComponent {
                        job_type: 1,
                        weight: 2.0,
                    },
                    MixComponent {
                        job_type: 3,
                        weight: 1.0,
                    },
                ],
                arrival_rate: 4.0,
                rate_phases: vec![(6.0, 0.25), (2.0, 4.0)],
                small_tasks: true,
            },
            pool_capacity: 120,
            policy_set: PolicySetSpec::Auto,
            jobs: 250,
            tags: Vec::new(),
            migration: MigrationPolicy::disabled(),
        }
    }

    #[test]
    fn roundtrip_preserves_spec() {
        let s = sample();
        s.validate().unwrap();
        let j = s.to_json();
        let back = ScenarioSpec::from_json(&j).unwrap();
        assert_eq!(back, s);
        // And via text.
        let re = ScenarioSpec::parse(&j.pretty()).unwrap();
        assert_eq!(re, s);
    }

    #[test]
    fn tags_roundtrip_and_stay_off_disk_when_empty() {
        // Untagged specs serialize exactly as before the key existed.
        let plain = sample().to_json().pretty();
        assert!(!plain.contains("\"tags\""), "{plain}");
        // Tagged specs round-trip.
        let mut s = sample();
        s.tags = vec!["calm".into(), "surge".into()];
        s.validate().unwrap();
        let back = ScenarioSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
        let re = ScenarioSpec::parse(&s.to_json().pretty()).unwrap();
        assert_eq!(re, s);
        // Empty and duplicate tags are rejected.
        let mut bad = sample();
        bad.tags = vec!["".into()];
        assert!(bad.validate().is_err());
        let mut dup = sample();
        dup.tags = vec!["calm".into(), "calm".into()];
        assert!(dup.validate().is_err());
        // Non-string tags error at parse time.
        let mut j = sample().to_json();
        j.set("tags", Json::Arr(vec![Json::Num(3.0)]));
        assert!(ScenarioSpec::from_json(&j).is_err());
    }

    #[test]
    fn replay_roundtrip() {
        let mut s = sample();
        s.market = MarketSpec {
            regions: vec![RegionSpec {
                name: "replayed".into(),
                od_price: 1.0,
                price: PriceSpec::Replay(ReplaySpec::inline("0,0.2\n5,0.5\n")),
                capacity: None,
                instance_types: Vec::new(),
            }],
            routing: RoutingSpec::Home,
        };
        s.validate().unwrap();
        let back = ScenarioSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
        // Default format/normalize stay off-disk (old spec files keep
        // parsing and old writers keep diffing clean).
        let pj = s.to_json().pretty();
        assert!(!pj.contains("\"format\""), "{pj}");
        assert!(!pj.contains("\"normalize\""), "{pj}");
    }

    #[test]
    fn ec2_replay_format_roundtrips_and_validates() {
        let mut s = sample();
        let mut rp = ReplaySpec::inline("{\"Timestamp\":\"2024-03-01T00:00:00Z\",\"SpotPrice\":\"0.03\"}");
        rp.format = ReplayFormat::Ec2Json;
        rp.time_scale = 1.0 / 3600.0;
        rp.price_scale = 10.0;
        s.market = MarketSpec {
            regions: vec![RegionSpec {
                name: "streamed".into(),
                od_price: 1.0,
                price: PriceSpec::Replay(rp.clone()),
                capacity: None,
                instance_types: Vec::new(),
            }],
            routing: RoutingSpec::Home,
        };
        s.validate().unwrap();
        let back = ScenarioSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
        let re = ScenarioSpec::parse(&s.to_json().pretty()).unwrap();
        assert_eq!(re, s);
        // normalize + EC2 format contradict (EC2 loaders always normalize).
        let mut bad = s.clone();
        if let PriceSpec::Replay(r) = &mut bad.market.regions[0].price {
            r.normalize = true;
        }
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("normalize"), "{err}");
        // Unknown format string errors.
        let text = s.to_json().pretty().replace("ec2-json", "parquet");
        assert!(ScenarioSpec::parse(&text).is_err());
        // The simple-format normalize flag round-trips.
        let mut s2 = sample();
        let mut rp2 = ReplaySpec::inline("5,0.3\n0,0.2\n");
        rp2.normalize = true;
        s2.market.regions[0].price = PriceSpec::Replay(rp2);
        s2.validate().unwrap();
        assert_eq!(ScenarioSpec::from_json(&s2.to_json()).unwrap(), s2);
    }

    #[test]
    fn replay_series_filters_roundtrip_and_validate() {
        // az/instance_type filters round-trip on EC2 formats...
        let mut s = sample();
        let mut rp = ReplaySpec::inline(
            "{\"Timestamp\":\"2024-03-01T00:00:00Z\",\"SpotPrice\":\"0.03\",\
             \"AvailabilityZone\":\"us-east-1a\",\"InstanceType\":\"m5.large\"}",
        );
        rp.format = ReplayFormat::Ec2Json;
        rp.az = Some("us-east-1a".into());
        rp.instance_type = Some("m5.large".into());
        s.market = MarketSpec {
            regions: vec![RegionSpec {
                name: "filtered".into(),
                od_price: 1.0,
                price: PriceSpec::Replay(rp.clone()),
                capacity: None,
                instance_types: Vec::new(),
            }],
            routing: RoutingSpec::Home,
        };
        s.validate().unwrap();
        assert_eq!(ScenarioSpec::from_json(&s.to_json()).unwrap(), s);
        let re = ScenarioSpec::parse(&s.to_json().pretty()).unwrap();
        assert_eq!(re, s);
        // ...stay off-disk when absent (old spec files keep diffing clean)...
        let plain = sample().to_json().pretty();
        assert!(!plain.contains("\"az\""), "{plain}");
        assert!(!plain.contains("\"instance_type\""), "{plain}");
        // ...and are rejected on the simple format, which has no series.
        let mut bad = s.clone();
        if let PriceSpec::Replay(r) = &mut bad.market.regions[0].price {
            r.format = ReplayFormat::Simple;
        }
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("EC2 formats only"), "{err}");
    }

    /// A capacity-and-instance-type market for the routed-world tests.
    fn routed_sample() -> ScenarioSpec {
        let mut s = sample();
        s.market = MarketSpec {
            regions: vec![
                RegionSpec {
                    name: "us-east".into(),
                    od_price: 1.0,
                    price: PriceSpec::Model(SpotModel::paper_default()),
                    capacity: Some(32),
                    instance_types: vec![InstanceTypeSpec {
                        name: "burst".into(),
                        od_price: Some(0.95),
                        price: PriceSpec::Model(SpotModel::BoundedExp {
                            mean: 0.4,
                            lo: 0.12,
                            hi: 1.0,
                        }),
                        capacity: Some(16),
                    }],
                },
                RegionSpec {
                    name: "eu-west".into(),
                    od_price: 1.15,
                    price: PriceSpec::Model(SpotModel::paper_default()),
                    capacity: None,
                    instance_types: Vec::new(),
                },
            ],
            routing: RoutingSpec::Cheapest,
        };
        s
    }

    #[test]
    fn routed_market_roundtrips_and_flattens() {
        let s = routed_sample();
        s.validate().unwrap();
        let back = ScenarioSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
        let re = ScenarioSpec::parse(&s.to_json().pretty()).unwrap();
        assert_eq!(re, s);
        let offers = s.market.flattened_offers();
        assert_eq!(offers.len(), 3);
        assert_eq!(offers[0].instance_type, "default");
        assert_eq!(offers[1].instance_type, "burst");
        assert_eq!(offers[1].od_price, 0.95);
        assert_eq!(offers[1].capacity, Some(16));
        assert_eq!(offers[2].region, "eu-west");
        assert_eq!(offers[2].od_price, 1.15, "inherits the region od price");
    }

    /// Mutate the market object of a serialized spec (test helper).
    fn with_market_key(spec: &ScenarioSpec, key: &str, value: Option<Json>) -> Json {
        let mut j = spec.to_json();
        if let Json::Obj(top) = &mut j {
            if let Some(Json::Obj(market)) = top.get_mut("market") {
                match value {
                    Some(v) => {
                        market.insert(key.to_string(), v);
                    }
                    None => {
                        market.remove(key);
                    }
                }
            }
        }
        j
    }

    #[test]
    fn routing_json_compat_and_conflicts() {
        let spec = sample(); // routing: Arbitrage
        // Old one-bit schema (no 'routing' key) still parses.
        let old = with_market_key(&spec, "routing", None);
        let s = ScenarioSpec::from_json(&old).unwrap();
        assert_eq!(s.market.routing, RoutingSpec::Arbitrage);
        // And the no-arbitrage old form maps to Home.
        let mut plain = with_market_key(&spec, "routing", None);
        if let Json::Obj(top) = &mut plain {
            if let Some(Json::Obj(market)) = top.get_mut("market") {
                market.insert("arbitrage".into(), Json::Bool(false));
            }
        }
        assert_eq!(
            ScenarioSpec::from_json(&plain).unwrap().market.routing,
            RoutingSpec::Home
        );
        // Conflicting keys are an error, not a silent pick.
        let conflicted = with_market_key(&spec, "arbitrage", Some(Json::Bool(false)));
        assert!(ScenarioSpec::from_json(&conflicted).is_err());
        // Unknown routing string is an error.
        let bogus = with_market_key(&spec, "routing", Some(Json::Str("teleport".into())));
        assert!(ScenarioSpec::from_json(&bogus).is_err());
        // Non-string routing is an error.
        let nonstr = with_market_key(&spec, "routing", Some(Json::Num(3.0)));
        assert!(ScenarioSpec::from_json(&nonstr).is_err());
    }

    #[test]
    fn capacity_and_instance_type_validation() {
        // capacity 0 is an error, not infinite.
        let mut s = routed_sample();
        s.market.regions[0].capacity = Some(0);
        assert!(s.validate().is_err());

        let mut s = routed_sample();
        s.market.regions[0].instance_types[0].capacity = Some(0);
        assert!(s.validate().is_err());

        // instance type may not shadow the default offer.
        let mut s = routed_sample();
        s.market.regions[0].instance_types[0].name = "default".into();
        assert!(s.validate().is_err());

        // duplicate instance type names in one region.
        let mut s = routed_sample();
        let dup = s.market.regions[0].instance_types[0].clone();
        s.market.regions[0].instance_types.push(dup);
        assert!(s.validate().is_err());

        // duplicate region names.
        let mut s = routed_sample();
        s.market.regions[1].name = "us-east".into();
        assert!(s.validate().is_err());

        // arbitrage + finite capacity contradict each other.
        let mut s = routed_sample();
        s.market.routing = RoutingSpec::Arbitrage;
        let err = s.validate().unwrap_err().to_string();
        assert!(err.contains("arbitrage"), "{err}");

        // a present-but-malformed instance-type od_price errors instead of
        // silently inheriting the region's price.
        let mut j = routed_sample().to_json();
        if let Json::Obj(top) = &mut j {
            if let Some(Json::Obj(market)) = top.get_mut("market") {
                if let Some(Json::Arr(regions)) = market.get_mut("regions") {
                    if let Some(Json::Obj(r0)) = regions.get_mut(0) {
                        if let Some(Json::Arr(its)) = r0.get_mut("instance_types") {
                            if let Some(it) = its.get_mut(0) {
                                it.set("od_price", Json::Str("0.9".into()));
                            }
                        }
                    }
                }
            }
        }
        let err = ScenarioSpec::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("od_price"), "{err}");

        // bad instance-type model params are caught with the offer path.
        let mut s = routed_sample();
        s.market.regions[0].instance_types[0].price =
            PriceSpec::Model(SpotModel::BoundedExp {
                mean: 0.3,
                lo: 0.9,
                hi: 0.2,
            });
        let err = s.validate().unwrap_err().to_string();
        assert!(err.contains("burst"), "{err}");
    }

    #[test]
    fn validation_catches_bad_specs() {
        let mut s = sample();
        s.workload.components.clear();
        assert!(s.validate().is_err());

        let mut s = sample();
        s.market.regions.clear();
        assert!(s.validate().is_err());

        let mut s = sample();
        s.workload.components[0].job_type = 9;
        assert!(s.validate().is_err());

        let mut s = sample();
        s.market.regions[0].price = PriceSpec::Replay(ReplaySpec {
            csv: None,
            ..ReplaySpec::inline("")
        });
        assert!(s.validate().is_err());

        let mut s = sample();
        s.jobs = 0;
        assert!(s.validate().is_err());

        // Degenerate price-process parameters fail validation, not the run.
        let mut s = sample();
        s.market.regions[0].price = PriceSpec::Model(SpotModel::BoundedExp {
            mean: 0.13,
            lo: 1.0,
            hi: 0.5,
        });
        assert!(s.validate().is_err());

        let mut s = sample();
        s.market.regions[0].price = PriceSpec::Model(SpotModel::GoogleFixed {
            price: 0.3,
            availability: 1.5,
        });
        assert!(s.validate().is_err());
    }

    #[test]
    fn missing_required_keys_error() {
        assert!(ScenarioSpec::parse("{}").is_err());
        assert!(ScenarioSpec::parse(r#"{"name":"x"}"#).is_err());
        assert!(PolicySetSpec::from_str("bogus").is_err());
    }

    #[test]
    fn out_of_range_numbers_rejected_not_truncated() {
        let mut j = sample().to_json();
        j.set("pool_capacity", Json::Num(4294967296.0)); // 2^32
        assert!(ScenarioSpec::from_json(&j).is_err());

        let text = sample()
            .to_json()
            .pretty()
            .replace("\"job_type\": 1", "\"job_type\": 258");
        assert!(ScenarioSpec::parse(&text).is_err());
    }
}
