//! Cross-scenario policy-robustness scoring.
//!
//! The paper's regret bound (Prop. B.1) is per-world: it says how fast the
//! learner closes on the best *fixed* policy of one market. A fleet run
//! answers the cross-world question the ROADMAP calls "scenario-level
//! regret comparisons": **which fixed policy is least bad across every
//! world at once?** For each policy label scored by the scenario cells
//! ([`ScenarioOutcome::policy_costs`]) this module computes, per world,
//! the mean fixed-policy regret normalized by the run-level Prop. B.1
//! bound, then aggregates across worlds:
//!
//! * the **worst-case** ratio (minimax ranking key),
//! * a **difficulty-weighted mean** — each world weighs in proportion to
//!   its bound-normalized policy-cost spread, so trivially-easy worlds
//!   (where every policy costs the same) cannot mask a regression,
//! * **tail-risk order statistics** over the per-world ratios: the
//!   P10/P50/P90 quantiles and CVaR₉₀ (the mean of the worst 10% of
//!   worlds), which is what large derived populations
//!   ([`crate::robustness::derive`]) are scored on.
//!
//! Determinism contract: given outcomes in canonical `(scenario,
//! replicate)` order, every accumulation below folds in a fixed order, so
//! the scores — and therefore the fleet report bytes — are independent of
//! how the cells were sharded or the shard reports merged (pinned by
//! `rust/tests/integration_fleet.rs`).

use std::collections::{BTreeMap, BTreeSet};

use crate::scenario::ScenarioOutcome;
use crate::util::json::Json;
use crate::util::stats::percentile;

/// One world's scoring inputs, distilled from its scenario cells: the
/// per-policy mean regret/bound ratio, the world's difficulty weight, and
/// its regime tags. Shared between [`score`] here and the cross-regime
/// promotion gate ([`crate::robustness::gate`]) so the two can never
/// disagree on how a ratio is computed.
#[derive(Debug, Clone, PartialEq)]
pub struct WorldStat {
    pub world: String,
    /// Union of the world's row tags, sorted (rows of one world share the
    /// spec's tags, but the union keeps mixed legacy rows well-defined).
    pub tags: Vec<String>,
    /// Bound-normalized difficulty: mean over the world's runs of
    /// `(max policy cost − min policy cost) / bound` — how much the policy
    /// grid spreads in this world, on the Prop. B.1 scale. Zero for worlds
    /// where every policy costs the same (they carry no ranking signal).
    pub difficulty: f64,
    /// Per-policy mean regret/bound ratio across the world's runs.
    pub policy_mean_ratio: BTreeMap<String, f64>,
}

/// Distill outcomes into per-world scoring stats, worlds in sorted order.
/// Runs without per-policy costs (rows from pre-fleet reports) or with a
/// non-positive bound are skipped.
pub fn world_table(outcomes: &[ScenarioOutcome]) -> Vec<WorldStat> {
    // world -> (policy -> (ratio sum, runs), spread sum, runs, tags)
    struct Acc<'a> {
        per_policy: BTreeMap<&'a str, (f64, u64)>,
        spread_sum: f64,
        runs: u64,
        tags: BTreeSet<&'a str>,
    }
    let mut per_world: BTreeMap<&str, Acc> = BTreeMap::new();
    for o in outcomes {
        if o.policy_costs.is_empty() || !(o.regret_bound > 0.0) {
            continue;
        }
        let min = o
            .policy_costs
            .iter()
            .map(|(_, c)| *c)
            .fold(f64::INFINITY, f64::min);
        let max = o
            .policy_costs
            .iter()
            .map(|(_, c)| *c)
            .fold(f64::NEG_INFINITY, f64::max);
        let acc = per_world.entry(o.scenario.as_str()).or_insert_with(|| Acc {
            per_policy: BTreeMap::new(),
            spread_sum: 0.0,
            runs: 0,
            tags: BTreeSet::new(),
        });
        acc.spread_sum += (max - min) / o.regret_bound;
        acc.runs += 1;
        acc.tags.extend(o.tags.iter().map(String::as_str));
        for (label, cost) in &o.policy_costs {
            let ratio = (cost - min) / o.regret_bound;
            let e = acc.per_policy.entry(label.as_str()).or_insert((0.0, 0));
            e.0 += ratio;
            e.1 += 1;
        }
    }
    per_world
        .into_iter()
        .map(|(world, acc)| WorldStat {
            world: world.to_string(),
            tags: acc.tags.into_iter().map(String::from).collect(),
            difficulty: acc.spread_sum / acc.runs as f64,
            policy_mean_ratio: acc
                .per_policy
                .into_iter()
                .map(|(l, (sum, runs))| (l.to_string(), sum / runs as f64))
                .collect(),
        })
        .collect()
}

/// One policy's cross-world robustness summary.
#[derive(Debug, Clone, PartialEq)]
pub struct PolicyScore {
    /// The policy label (the grammar scenario reports key on).
    pub policy: String,
    /// Worlds in which this policy was scored.
    pub worlds: usize,
    /// Max over worlds of the world-mean regret/bound ratio.
    pub worst_regret_ratio: f64,
    /// Difficulty-weighted mean over covered worlds of the world-mean
    /// regret/bound ratio (uniform fallback when every covered world has
    /// zero difficulty).
    pub mean_regret_ratio: f64,
    /// P10 / P50 / P90 of the per-world mean ratios (linear interpolation).
    pub ratio_p10: f64,
    pub ratio_p50: f64,
    pub ratio_p90: f64,
    /// CVaR₉₀: mean of the worst `ceil(worlds/10)` per-world ratios — the
    /// expected ratio given the world landed in the worst decile.
    pub cvar90: f64,
    /// The world realizing `worst_regret_ratio`.
    pub worst_world: String,
    /// Mean capacity-replay optimism gap (`replayed − free` cost, ≥ 0)
    /// across every row that replayed this policy — `None` when no covered
    /// row carried a gap (capacity-free fleets), keeping legacy report
    /// bytes unchanged.
    pub optimism_gap_mean: Option<f64>,
    /// Worlds this policy was *not* scored in (empty when fully covered) —
    /// the cells a partial-coverage policy misses.
    pub missing_worlds: Vec<String>,
    /// 1-based least-bad rank; `None` for policies not scored in every
    /// world (their worst case is not comparable).
    pub rank: Option<usize>,
}

/// The cross-world scoring result: the per-policy scores in ranking
/// order plus the world count the coverage/rank notion was computed
/// against (the same count [`robustness_json`] emits, so the two can
/// never drift apart).
#[derive(Debug, Clone, PartialEq)]
pub struct Robustness {
    /// Worlds with at least one scorable run (per-policy costs present,
    /// positive bound) — the denominator of "fully covered".
    pub worlds: usize,
    /// Ranking order: fully-covered policies first in least-bad
    /// (minimax) order, then partially-covered ones by coverage.
    pub scores: Vec<PolicyScore>,
}

/// Score every policy label appearing in the outcomes' `policy_costs`.
///
/// Per run, a fixed policy's regret is its mean counterfactual cost per
/// job minus the run's cheapest fixed policy's; the ratio divides by the
/// run's Prop. B.1 bound so worlds with different job counts and horizons
/// compare on one scale. Runs without per-policy costs (rows from
/// pre-fleet reports) or with a non-positive bound are skipped.
///
/// `outcomes` must be canonically sorted (`(scenario, replicate)`), as
/// [`super::merge::FleetAccumulator`] guarantees.
pub fn score(outcomes: &[ScenarioOutcome]) -> Robustness {
    let table = world_table(outcomes);
    let total_worlds = table.len();

    // Per-policy capacity-replay gap accumulation: outcomes arrive in
    // canonical order, so the fold order (and the resulting bytes) are
    // shard- and merge-order-independent like everything else here.
    let mut gap_acc: BTreeMap<&str, (f64, u64)> = BTreeMap::new();
    for o in outcomes {
        for (label, gap) in &o.optimism_gap {
            let e = gap_acc.entry(label.as_str()).or_insert((0.0, 0));
            e.0 += gap;
            e.1 += 1;
        }
    }

    // policy -> per-world (ratio, difficulty) pairs, worlds iterated in
    // sorted order so the cross-world folds are order-fixed.
    let mut per_policy: BTreeMap<&str, Vec<(&str, f64, f64)>> = BTreeMap::new();
    for w in &table {
        for (label, &ratio) in &w.policy_mean_ratio {
            per_policy
                .entry(label.as_str())
                .or_default()
                .push((w.world.as_str(), ratio, w.difficulty));
        }
    }

    let mut scores: Vec<PolicyScore> = per_policy
        .into_iter()
        .map(|(label, rows)| {
            let ratios: Vec<f64> = rows.iter().map(|(_, r, _)| *r).collect();
            let mut worst = f64::NEG_INFINITY;
            let mut worst_world = "";
            for (w, r, _) in &rows {
                if *r > worst {
                    worst = *r;
                    worst_world = w;
                }
            }
            let total_difficulty: f64 = rows.iter().map(|(_, _, d)| *d).sum();
            let mean = if total_difficulty > 0.0 {
                rows.iter().map(|(_, r, d)| r * d).sum::<f64>() / total_difficulty
            } else {
                ratios.iter().sum::<f64>() / ratios.len() as f64
            };
            // Worst decile: at least one world, sorted descending so the
            // fold order is fixed.
            let mut tail = ratios.clone();
            tail.sort_by(|a, b| b.total_cmp(a));
            let k = (ratios.len() + 9) / 10; // ceil(n/10), at least 1
            let cvar90 = tail[..k].iter().sum::<f64>() / k as f64;
            let covered: BTreeSet<&str> = rows.iter().map(|(w, _, _)| *w).collect();
            let missing_worlds: Vec<String> = table
                .iter()
                .filter(|w| !covered.contains(w.world.as_str()))
                .map(|w| w.world.clone())
                .collect();
            PolicyScore {
                policy: label.to_string(),
                worlds: rows.len(),
                worst_regret_ratio: worst,
                mean_regret_ratio: mean,
                ratio_p10: percentile(&ratios, 10.0),
                ratio_p50: percentile(&ratios, 50.0),
                ratio_p90: percentile(&ratios, 90.0),
                cvar90,
                worst_world: worst_world.to_string(),
                optimism_gap_mean: gap_acc
                    .get(label)
                    .map(|(sum, runs)| sum / *runs as f64),
                missing_worlds,
                rank: None,
            }
        })
        .collect();

    // Least-bad (minimax) order for fully-covered policies; partial
    // coverage sorts after, by coverage then the same keys. Ties break on
    // the label so the order is total.
    scores.sort_by(|a, b| {
        let full_a = a.worlds == total_worlds;
        let full_b = b.worlds == total_worlds;
        full_b
            .cmp(&full_a)
            .then(b.worlds.cmp(&a.worlds))
            .then(a.worst_regret_ratio.total_cmp(&b.worst_regret_ratio))
            .then(a.mean_regret_ratio.total_cmp(&b.mean_regret_ratio))
            .then(a.policy.cmp(&b.policy))
    });
    let mut rank = 0usize;
    for s in &mut scores {
        if s.worlds == total_worlds && total_worlds > 0 {
            rank += 1;
            s.rank = Some(rank);
        }
    }
    Robustness {
        worlds: total_worlds,
        scores,
    }
}

/// Serialize the scoring result as the fleet report's `robustness`
/// section. The quantile/CVaR keys are additive within
/// `dagcloud.fleet/v1` (schema policy rule: optional keys may be added
/// without a version bump); `missing_worlds` appears only on
/// partial-coverage policies, so fully-covered entries keep a stable
/// shape.
pub fn robustness_json(r: &Robustness) -> Json {
    let mut j = Json::obj();
    j.set("worlds", Json::Num(r.worlds as f64))
        .set(
            "ranked",
            Json::Num(r.scores.iter().filter(|s| s.rank.is_some()).count() as f64),
        )
        .set(
            "policies",
            Json::Arr(
                r.scores
                    .iter()
                    .map(|s| {
                        let mut sj = Json::obj();
                        sj.set("policy", Json::Str(s.policy.clone()))
                            .set("worlds", Json::Num(s.worlds as f64))
                            .set("worst_regret_ratio", Json::Num(s.worst_regret_ratio))
                            .set("mean_regret_ratio", Json::Num(s.mean_regret_ratio))
                            .set("ratio_p10", Json::Num(s.ratio_p10))
                            .set("ratio_p50", Json::Num(s.ratio_p50))
                            .set("ratio_p90", Json::Num(s.ratio_p90))
                            .set("cvar90", Json::Num(s.cvar90))
                            .set("worst_world", Json::Str(s.worst_world.clone()));
                        if let Some(g) = s.optimism_gap_mean {
                            sj.set("optimism_gap_mean", Json::Num(g));
                        }
                        if !s.missing_worlds.is_empty() {
                            sj.set(
                                "missing_worlds",
                                Json::Arr(
                                    s.missing_worlds
                                        .iter()
                                        .map(|w| Json::Str(w.clone()))
                                        .collect(),
                                ),
                            );
                        }
                        if let Some(r) = s.rank {
                            sj.set("rank", Json::Num(r as f64));
                        }
                        sj
                    })
                    .collect(),
            ),
        );
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(world: &str, rep: u64, costs: &[(&str, f64)], bound: f64) -> ScenarioOutcome {
        ScenarioOutcome {
            scenario: world.into(),
            replicate: rep,
            run_seed: rep,
            jobs: 10,
            average_unit_cost: 0.3,
            average_regret: 0.01,
            regret_bound: bound,
            pool_utilization: 0.0,
            so_share: 0.0,
            spot_share: 0.8,
            od_share: 0.2,
            availability_lo: 0.4,
            availability_hi: 0.9,
            best_policy: costs.first().map(|(l, _)| l.to_string()).unwrap_or_default(),
            offer_shares: Vec::new(),
            policy_costs: costs.iter().map(|(l, c)| (l.to_string(), *c)).collect(),
            tags: Vec::new(),
            optimism_gap: Vec::new(),
            migrations: 0,
        }
    }

    #[test]
    fn optimism_gap_mean_surfaces_per_policy_only_when_replayed() {
        let mut a = outcome("w1", 0, &[("p1", 0.1), ("p2", 0.2)], 0.5);
        let b = outcome("w2", 0, &[("p1", 0.2), ("p2", 0.2)], 0.5);
        // Capacity-free rows: no gap anywhere, and the key stays off-disk.
        let r = score(&[a.clone(), b.clone()]);
        assert!(r.scores.iter().all(|s| s.optimism_gap_mean.is_none()));
        let j = robustness_json(&r);
        let pol = j.get("policies").unwrap().as_arr().unwrap();
        assert!(pol.iter().all(|p| p.get("optimism_gap_mean").is_none()));
        // One capped world replayed p1 twice and p2 once: means fold per
        // policy over exactly the rows that replayed it.
        a.optimism_gap = vec![("p1".into(), 0.02), ("p2".into(), 0.0)];
        let mut a2 = outcome("w1", 1, &[("p1", 0.1), ("p2", 0.2)], 0.5);
        a2.optimism_gap = vec![("p1".into(), 0.04)];
        let r = score(&[a, a2, b]);
        let p1 = r.scores.iter().find(|s| s.policy == "p1").unwrap();
        assert!((p1.optimism_gap_mean.unwrap() - 0.03).abs() < 1e-15);
        let p2 = r.scores.iter().find(|s| s.policy == "p2").unwrap();
        assert_eq!(p2.optimism_gap_mean, Some(0.0));
        let j = robustness_json(&r);
        let pol = j.get("policies").unwrap().as_arr().unwrap();
        assert!(pol.iter().any(|p| p.get("optimism_gap_mean").is_some()));
    }

    #[test]
    fn minimax_ranking_picks_the_least_bad_policy() {
        // p1 is best in w1 but terrible in w2; p2 is mediocre everywhere.
        let outs = vec![
            outcome("w1", 0, &[("p1", 0.10), ("p2", 0.20)], 0.5),
            outcome("w2", 0, &[("p1", 0.90), ("p2", 0.30)], 0.5),
        ];
        let r = score(&outs);
        assert_eq!(r.worlds, 2);
        let scores = r.scores;
        assert_eq!(scores.len(), 2);
        assert_eq!(scores[0].policy, "p2", "least-bad is p2");
        assert_eq!(scores[0].rank, Some(1));
        assert_eq!(scores[0].worst_world, "w1");
        assert!((scores[0].worst_regret_ratio - 0.1 / 0.5).abs() < 1e-12);
        assert_eq!(scores[1].policy, "p1");
        assert_eq!(scores[1].worst_world, "w2");
        assert!((scores[1].worst_regret_ratio - 0.6 / 0.5).abs() < 1e-12);
        assert!(scores[0].missing_worlds.is_empty());
    }

    #[test]
    fn replicates_average_and_partial_coverage_lists_missing_cells() {
        let outs = vec![
            outcome("w1", 0, &[("p1", 0.1), ("p2", 0.3)], 1.0),
            outcome("w1", 1, &[("p1", 0.1), ("p2", 0.5)], 1.0),
            // p3 exists only in w2: scored but unranked.
            outcome("w2", 0, &[("p1", 0.2), ("p2", 0.2), ("p3", 0.4)], 1.0),
        ];
        let scores = score(&outs).scores;
        let p2 = scores.iter().find(|s| s.policy == "p2").unwrap();
        // w1 ratios: (0.2 + 0.4)/2 = 0.3; w2: 0.0 -> worst 0.3. The mean
        // is difficulty-weighted: w1 spread ratio (0.2 + 0.4)/2 = 0.3, w2
        // spread 0.2 -> mean = (0.3*0.3 + 0.0*0.2)/0.5 = 0.18.
        assert!((p2.worst_regret_ratio - 0.3).abs() < 1e-12);
        assert!((p2.mean_regret_ratio - 0.18).abs() < 1e-12);
        let p3 = scores.iter().find(|s| s.policy == "p3").unwrap();
        assert_eq!(p3.rank, None);
        assert_eq!(p3.worlds, 1);
        assert_eq!(p3.missing_worlds, vec!["w1".to_string()]);
        // Ranked policies come first.
        assert!(scores[0].rank.is_some() && scores[1].rank.is_some());
        assert_eq!(scores[2].policy, "p3");
    }

    #[test]
    fn difficulty_weighting_discounts_trivially_easy_worlds() {
        // w-easy: all policies identical (spread 0 -> difficulty 0).
        // w-hard: p2 is clearly worse. Uniform weighting would halve p2's
        // mean; difficulty weighting keeps the hard world's full signal.
        let outs = vec![
            outcome("w-easy", 0, &[("p1", 0.2), ("p2", 0.2)], 1.0),
            outcome("w-hard", 0, &[("p1", 0.1), ("p2", 0.5)], 1.0),
        ];
        let scores = score(&outs).scores;
        let p2 = scores.iter().find(|s| s.policy == "p2").unwrap();
        assert!((p2.mean_regret_ratio - 0.4).abs() < 1e-12, "easy world masked the regression");
        // All-zero difficulty falls back to the uniform mean.
        let outs = vec![
            outcome("w1", 0, &[("p1", 0.2), ("p2", 0.2)], 1.0),
            outcome("w2", 0, &[("p1", 0.3), ("p2", 0.3)], 1.0),
        ];
        let scores = score(&outs).scores;
        assert_eq!(scores[0].mean_regret_ratio, 0.0);
    }

    #[test]
    fn quantiles_and_cvar_summarize_the_tail() {
        // 10 worlds; p1's ratio in world k is k/10 (p0 is the floor).
        let mut outs = Vec::new();
        for k in 0..10 {
            outs.push(outcome(
                &format!("w{k:02}"),
                0,
                &[("p0", 0.0), ("p1", k as f64 / 10.0)],
                1.0,
            ));
        }
        let scores = score(&outs).scores;
        let p1 = scores.iter().find(|s| s.policy == "p1").unwrap();
        assert_eq!(p1.worlds, 10);
        assert!((p1.worst_regret_ratio - 0.9).abs() < 1e-12);
        // Linear-interpolation percentiles over {0.0, 0.1, .., 0.9}.
        assert!((p1.ratio_p50 - 0.45).abs() < 1e-12);
        assert!((p1.ratio_p10 - 0.09).abs() < 1e-12);
        assert!((p1.ratio_p90 - 0.81).abs() < 1e-12);
        // Worst decile of 10 worlds is the single worst world.
        assert!((p1.cvar90 - 0.9).abs() < 1e-12);
        // The floor policy is flat: every statistic collapses to 0.
        let p0 = scores.iter().find(|s| s.policy == "p0").unwrap();
        assert_eq!(p0.cvar90, 0.0);
        assert_eq!(p0.ratio_p90, 0.0);
    }

    #[test]
    fn world_table_collects_tags_and_difficulty() {
        let mut a = outcome("w1", 0, &[("p1", 0.1), ("p2", 0.3)], 0.5);
        a.tags = vec!["calm".into(), "surge".into()];
        let mut b = outcome("w1", 1, &[("p1", 0.1), ("p2", 0.3)], 0.5);
        b.tags = vec!["calm".into()];
        let table = world_table(&[a, b]);
        assert_eq!(table.len(), 1);
        assert_eq!(table[0].world, "w1");
        assert_eq!(table[0].tags, vec!["calm".to_string(), "surge".to_string()]);
        assert!((table[0].difficulty - 0.4).abs() < 1e-12, "spread 0.2/bound 0.5");
        assert!((table[0].policy_mean_ratio["p2"] - 0.4).abs() < 1e-12);
    }

    #[test]
    fn rows_without_costs_or_bound_are_skipped() {
        let mut no_costs = outcome("w1", 0, &[], 1.0);
        no_costs.policy_costs.clear();
        let no_bound = outcome("w2", 0, &[("p1", 0.1)], 0.0);
        let r = score(&[no_costs, no_bound]);
        assert!(r.scores.is_empty());
        assert_eq!(r.worlds, 0);
    }

    #[test]
    fn json_shape_is_stable() {
        let outs = vec![outcome("w1", 0, &[("p1", 0.1), ("p2", 0.2)], 1.0)];
        let j = robustness_json(&score(&outs));
        assert_eq!(j.get("worlds").unwrap().as_u64().unwrap(), 1);
        assert_eq!(j.get("ranked").unwrap().as_u64().unwrap(), 2);
        let arr = j.get("policies").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].get("policy").unwrap().as_str().unwrap(), "p1");
        assert_eq!(arr[0].get("rank").unwrap().as_u64().unwrap(), 1);
        assert_eq!(arr[0].get("cvar90").unwrap().as_f64().unwrap(), 0.0);
        assert!(arr[0].get("ratio_p10").is_some());
        assert!(arr[0].get("ratio_p90").is_some());
        // Fully-covered policies carry no missing_worlds key.
        assert!(arr[0].get("missing_worlds").is_none());
    }
}
