//! Cross-scenario policy-robustness scoring.
//!
//! The paper's regret bound (Prop. B.1) is per-world: it says how fast the
//! learner closes on the best *fixed* policy of one market. A fleet run
//! answers the cross-world question the ROADMAP calls "scenario-level
//! regret comparisons": **which fixed policy is least bad across every
//! world at once?** For each policy label scored by the scenario cells
//! ([`ScenarioOutcome::policy_costs`]) this module computes, per world,
//! the mean fixed-policy regret normalized by the run-level Prop. B.1
//! bound, then aggregates the worst-case and mean ratios across worlds
//! and ranks the policies minimax (worst-case first).
//!
//! Determinism contract: given outcomes in canonical `(scenario,
//! replicate)` order, every accumulation below folds in a fixed order, so
//! the scores — and therefore the fleet report bytes — are independent of
//! how the cells were sharded or the shard reports merged (pinned by
//! `rust/tests/integration_fleet.rs`).

use std::collections::BTreeMap;

use crate::scenario::ScenarioOutcome;
use crate::util::json::Json;

/// One policy's cross-world robustness summary.
#[derive(Debug, Clone, PartialEq)]
pub struct PolicyScore {
    /// The policy label (the grammar scenario reports key on).
    pub policy: String,
    /// Worlds in which this policy was scored.
    pub worlds: usize,
    /// Max over worlds of the world-mean regret/bound ratio.
    pub worst_regret_ratio: f64,
    /// Mean over covered worlds of the world-mean regret/bound ratio.
    pub mean_regret_ratio: f64,
    /// The world realizing `worst_regret_ratio`.
    pub worst_world: String,
    /// 1-based least-bad rank; `None` for policies not scored in every
    /// world (their worst case is not comparable).
    pub rank: Option<usize>,
}

/// The cross-world scoring result: the per-policy scores in ranking
/// order plus the world count the coverage/rank notion was computed
/// against (the same count [`robustness_json`] emits, so the two can
/// never drift apart).
#[derive(Debug, Clone, PartialEq)]
pub struct Robustness {
    /// Worlds with at least one scorable run (per-policy costs present,
    /// positive bound) — the denominator of "fully covered".
    pub worlds: usize,
    /// Ranking order: fully-covered policies first in least-bad
    /// (minimax) order, then partially-covered ones by coverage.
    pub scores: Vec<PolicyScore>,
}

/// Score every policy label appearing in the outcomes' `policy_costs`.
///
/// Per run, a fixed policy's regret is its mean counterfactual cost per
/// job minus the run's cheapest fixed policy's; the ratio divides by the
/// run's Prop. B.1 bound so worlds with different job counts and horizons
/// compare on one scale. Runs without per-policy costs (rows from
/// pre-fleet reports) or with a non-positive bound are skipped.
///
/// `outcomes` must be canonically sorted (`(scenario, replicate)`), as
/// [`super::merge::FleetAccumulator`] guarantees.
pub fn score(outcomes: &[ScenarioOutcome]) -> Robustness {
    // world -> policy -> (ratio sum, run count), worlds in sorted order.
    let mut per_world: BTreeMap<&str, BTreeMap<&str, (f64, u64)>> = BTreeMap::new();
    for o in outcomes {
        if o.policy_costs.is_empty() || !(o.regret_bound > 0.0) {
            continue;
        }
        let min = o
            .policy_costs
            .iter()
            .map(|(_, c)| *c)
            .fold(f64::INFINITY, f64::min);
        let world = per_world.entry(o.scenario.as_str()).or_default();
        for (label, cost) in &o.policy_costs {
            let ratio = (cost - min) / o.regret_bound;
            let e = world.entry(label.as_str()).or_insert((0.0, 0));
            e.0 += ratio;
            e.1 += 1;
        }
    }
    let total_worlds = per_world.len();

    // policy -> per-world mean ratios, worlds iterated in sorted order so
    // the cross-world folds are order-fixed.
    let mut acc: BTreeMap<&str, PolicyScore> = BTreeMap::new();
    for (&world, policies) in &per_world {
        for (&label, &(sum, runs)) in policies {
            let world_mean = sum / runs as f64;
            let s = acc.entry(label).or_insert_with(|| PolicyScore {
                policy: label.to_string(),
                worlds: 0,
                worst_regret_ratio: f64::NEG_INFINITY,
                mean_regret_ratio: 0.0,
                worst_world: String::new(),
                rank: None,
            });
            s.worlds += 1;
            s.mean_regret_ratio += world_mean; // finalized below
            if world_mean > s.worst_regret_ratio {
                s.worst_regret_ratio = world_mean;
                s.worst_world = world.to_string();
            }
        }
    }
    let mut scores: Vec<PolicyScore> = acc
        .into_values()
        .map(|mut s| {
            s.mean_regret_ratio /= s.worlds as f64;
            s
        })
        .collect();

    // Least-bad (minimax) order for fully-covered policies; partial
    // coverage sorts after, by coverage then the same keys. Ties break on
    // the label so the order is total.
    scores.sort_by(|a, b| {
        let full_a = a.worlds == total_worlds;
        let full_b = b.worlds == total_worlds;
        full_b
            .cmp(&full_a)
            .then(b.worlds.cmp(&a.worlds))
            .then(a.worst_regret_ratio.total_cmp(&b.worst_regret_ratio))
            .then(a.mean_regret_ratio.total_cmp(&b.mean_regret_ratio))
            .then(a.policy.cmp(&b.policy))
    });
    let mut rank = 0usize;
    for s in &mut scores {
        if s.worlds == total_worlds && total_worlds > 0 {
            rank += 1;
            s.rank = Some(rank);
        }
    }
    Robustness {
        worlds: total_worlds,
        scores,
    }
}

/// Serialize the scoring result as the fleet report's `robustness`
/// section.
pub fn robustness_json(r: &Robustness) -> Json {
    let mut j = Json::obj();
    j.set("worlds", Json::Num(r.worlds as f64))
        .set(
            "ranked",
            Json::Num(r.scores.iter().filter(|s| s.rank.is_some()).count() as f64),
        )
        .set(
            "policies",
            Json::Arr(
                r.scores
                    .iter()
                    .map(|s| {
                        let mut sj = Json::obj();
                        sj.set("policy", Json::Str(s.policy.clone()))
                            .set("worlds", Json::Num(s.worlds as f64))
                            .set("worst_regret_ratio", Json::Num(s.worst_regret_ratio))
                            .set("mean_regret_ratio", Json::Num(s.mean_regret_ratio))
                            .set("worst_world", Json::Str(s.worst_world.clone()));
                        if let Some(r) = s.rank {
                            sj.set("rank", Json::Num(r as f64));
                        }
                        sj
                    })
                    .collect(),
            ),
        );
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(world: &str, rep: u64, costs: &[(&str, f64)], bound: f64) -> ScenarioOutcome {
        ScenarioOutcome {
            scenario: world.into(),
            replicate: rep,
            run_seed: rep,
            jobs: 10,
            average_unit_cost: 0.3,
            average_regret: 0.01,
            regret_bound: bound,
            pool_utilization: 0.0,
            so_share: 0.0,
            spot_share: 0.8,
            od_share: 0.2,
            availability_lo: 0.4,
            availability_hi: 0.9,
            best_policy: costs.first().map(|(l, _)| l.to_string()).unwrap_or_default(),
            offer_shares: Vec::new(),
            policy_costs: costs.iter().map(|(l, c)| (l.to_string(), *c)).collect(),
        }
    }

    #[test]
    fn minimax_ranking_picks_the_least_bad_policy() {
        // p1 is best in w1 but terrible in w2; p2 is mediocre everywhere.
        let outs = vec![
            outcome("w1", 0, &[("p1", 0.10), ("p2", 0.20)], 0.5),
            outcome("w2", 0, &[("p1", 0.90), ("p2", 0.30)], 0.5),
        ];
        let r = score(&outs);
        assert_eq!(r.worlds, 2);
        let scores = r.scores;
        assert_eq!(scores.len(), 2);
        assert_eq!(scores[0].policy, "p2", "least-bad is p2");
        assert_eq!(scores[0].rank, Some(1));
        assert_eq!(scores[0].worst_world, "w1");
        assert!((scores[0].worst_regret_ratio - 0.1 / 0.5).abs() < 1e-12);
        assert_eq!(scores[1].policy, "p1");
        assert_eq!(scores[1].worst_world, "w2");
        assert!((scores[1].worst_regret_ratio - 0.6 / 0.5).abs() < 1e-12);
    }

    #[test]
    fn replicates_average_and_partial_coverage_is_unranked() {
        let outs = vec![
            outcome("w1", 0, &[("p1", 0.1), ("p2", 0.3)], 1.0),
            outcome("w1", 1, &[("p1", 0.1), ("p2", 0.5)], 1.0),
            // p3 exists only in w2: scored but unranked.
            outcome("w2", 0, &[("p1", 0.2), ("p2", 0.2), ("p3", 0.4)], 1.0),
        ];
        let scores = score(&outs).scores;
        let p2 = scores.iter().find(|s| s.policy == "p2").unwrap();
        // w1 ratios: (0.2 + 0.4)/2 = 0.3; w2: 0.0 -> worst 0.3, mean 0.15.
        assert!((p2.worst_regret_ratio - 0.3).abs() < 1e-12);
        assert!((p2.mean_regret_ratio - 0.15).abs() < 1e-12);
        let p3 = scores.iter().find(|s| s.policy == "p3").unwrap();
        assert_eq!(p3.rank, None);
        assert_eq!(p3.worlds, 1);
        // Ranked policies come first.
        assert!(scores[0].rank.is_some() && scores[1].rank.is_some());
        assert_eq!(scores[2].policy, "p3");
    }

    #[test]
    fn rows_without_costs_or_bound_are_skipped() {
        let mut no_costs = outcome("w1", 0, &[], 1.0);
        no_costs.policy_costs.clear();
        let no_bound = outcome("w2", 0, &[("p1", 0.1)], 0.0);
        let r = score(&[no_costs, no_bound]);
        assert!(r.scores.is_empty());
        assert_eq!(r.worlds, 0);
    }

    #[test]
    fn json_shape_is_stable() {
        let outs = vec![outcome("w1", 0, &[("p1", 0.1), ("p2", 0.2)], 1.0)];
        let j = robustness_json(&score(&outs));
        assert_eq!(j.get("worlds").unwrap().as_u64().unwrap(), 1);
        assert_eq!(j.get("ranked").unwrap().as_u64().unwrap(), 2);
        let arr = j.get("policies").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].get("policy").unwrap().as_str().unwrap(), "p1");
        assert_eq!(arr[0].get("rank").unwrap().as_u64().unwrap(), 1);
    }
}
