//! The fleet layer: many coordinators, one report.
//!
//! The paper evaluates its policies across many market regimes (§6), and
//! its online-learning framing is convergence *across repeated
//! interactions with varying markets* — at platform scale that means a
//! fleet of coordinators, not one process. This module is the scale step
//! above [`crate::scenario`]:
//!
//! * [`manifest`] — a serialized [`ShardManifest`]
//!   (`dagcloud.fleet-manifest/v1`) dealing worlds round-robin to shards;
//!   each entry is self-contained (full embedded specs), so shards can be
//!   driven by separate processes later and merged with
//!   `repro fleet --merge-only`;
//! * [`merge`] — the [`FleetAccumulator`]: an associative,
//!   order-independent union of `dagcloud.scenarios/v1` shard reports
//!   into one `dagcloud.fleet/v1` document. Rows are keyed by
//!   `(scenario, replicate)`; the merged report is re-derived from the
//!   canonically sorted row set, so its bytes are invariant under shard
//!   count, shard partition, and merge order (property-tested in
//!   `rust/tests/integration_fleet.rs`). [`merge_online`] folds
//!   [`crate::coordinator::OnlineSnapshot`] streams (or serialized
//!   `dagcloud.feed/v1` reports) into a fleet-wide convergence timeline,
//!   and [`merge_health`] does the same for folded `dagcloud.health/v1`
//!   sections (duplicate sources are a hard error; the document is
//!   re-derived from the source-sorted set);
//! * [`robustness`] — cross-scenario policy-robustness scoring: per
//!   fixed policy, the worst-case and difficulty-weighted mean regret
//!   (normalized by the run-level Prop. B.1 bound) across all worlds,
//!   tail-risk quantiles (P10/P50/P90) and CVaR₉₀ over the per-world
//!   ratios, plus a least-bad (minimax) ranking. The per-world stats
//!   table ([`robustness::world_table`]) is shared with the cross-regime
//!   promotion gate in [`crate::robustness`].
//!
//! The CLI front-end is `repro fleet --shards K` (see
//! `rust/src/experiments/fleet.rs`); every report schema is documented
//! field-by-field in `docs/SCHEMAS.md`.

pub mod manifest;
pub mod merge;
pub mod robustness;

pub use manifest::{ShardManifest, ShardPlan};
pub use merge::{
    merge_health, merge_online, online_source_from_feed_report, FleetAccumulator,
    MergedOnline, MergedOnlinePoint, OnlineSource,
};
pub use robustness::{robustness_json, score, world_table, PolicyScore, Robustness, WorldStat};
