//! The serialized shard manifest: which coordinator runs which worlds.
//!
//! `repro fleet` drives its shards as process-internal coordinators today,
//! but the manifest (`dagcloud.fleet-manifest/v1`) is written to disk
//! first and is fully self-contained — every shard entry embeds the
//! complete [`ScenarioSpec`]s it must run plus the batch parameters and
//! the report path it must write — so the same fleet can later be driven
//! by separate processes (one per shard, any machine) and merged with
//! `repro fleet --merge-only` without touching this code.
//!
//! Determinism: the plan is a pure function of `(specs, shards)` —
//! scenarios are dealt round-robin in declared order — and the merged
//! fleet report is independent of the sharding anyway (see
//! [`super::merge`]), so the shard count is a throughput knob, never a
//! results knob.

use anyhow::{anyhow, ensure, Result};

use crate::scenario::ScenarioSpec;
use crate::util::json::Json;

/// One shard: the worlds one coordinator runs and where its report goes.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardPlan {
    /// Shard index (dense, `0..shards`).
    pub shard: usize,
    /// Report file name, relative to the fleet's output directory.
    pub report: String,
    /// The complete specs this shard runs (self-contained: no registry
    /// lookup needed on the running side).
    pub scenarios: Vec<ScenarioSpec>,
}

/// The whole fleet plan.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardManifest {
    pub base_seed: u64,
    /// Replicates per scenario.
    pub seeds: u64,
    pub smoke: bool,
    /// Per-run job override (smoke / `--jobs`).
    pub jobs_override: Option<usize>,
    pub shards: Vec<ShardPlan>,
}

impl ShardManifest {
    /// Deal `specs` round-robin across (at most) `shards` coordinators.
    /// Requesting more shards than worlds yields one shard per world.
    pub fn plan(
        specs: &[ScenarioSpec],
        shards: usize,
        seeds: u64,
        base_seed: u64,
        smoke: bool,
        jobs_override: Option<usize>,
    ) -> Result<ShardManifest> {
        ensure!(shards >= 1, "fleet: --shards must be at least 1");
        ensure!(!specs.is_empty(), "fleet: no scenarios to shard");
        for (i, s) in specs.iter().enumerate() {
            s.validate()?;
            ensure!(
                !specs[..i].iter().any(|o| o.name == s.name),
                "fleet: duplicate scenario name '{}' (cells are keyed by name)",
                s.name
            );
        }
        let n = shards.min(specs.len());
        let mut plans: Vec<ShardPlan> = (0..n)
            .map(|k| ShardPlan {
                shard: k,
                report: format!("fleet_shard_{k}.json"),
                scenarios: Vec::new(),
            })
            .collect();
        for (i, s) in specs.iter().enumerate() {
            plans[i % n].scenarios.push(s.clone());
        }
        Ok(ShardManifest {
            base_seed,
            seeds: seeds.max(1),
            smoke,
            jobs_override,
            shards: plans,
        })
    }

    /// Total worlds across all shards.
    pub fn worlds(&self) -> usize {
        self.shards.iter().map(|s| s.scenarios.len()).sum()
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("schema", Json::Str("dagcloud.fleet-manifest/v1".into()))
            .set("base_seed", Json::Str(self.base_seed.to_string()))
            .set("seeds", Json::Num(self.seeds as f64))
            .set("smoke", Json::Bool(self.smoke));
        if let Some(jobs) = self.jobs_override {
            j.set("jobs_override", Json::Num(jobs as f64));
        }
        j.set(
            "shards",
            Json::Arr(
                self.shards
                    .iter()
                    .map(|s| {
                        let mut sj = Json::obj();
                        sj.set("shard", Json::Num(s.shard as f64))
                            .set("report", Json::Str(s.report.clone()))
                            .set(
                                "scenarios",
                                Json::Arr(
                                    s.scenarios.iter().map(ScenarioSpec::to_json).collect(),
                                ),
                            );
                        sj
                    })
                    .collect(),
            ),
        );
        j
    }

    pub fn from_json(j: &Json) -> Result<ShardManifest> {
        let schema = j.opt_str("schema", "");
        ensure!(
            schema == "dagcloud.fleet-manifest/v1",
            "expected schema dagcloud.fleet-manifest/v1, found '{schema}'"
        );
        let base_seed = j
            .get("base_seed")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("fleet manifest: missing string 'base_seed'"))?
            .parse::<u64>()
            .map_err(|e| anyhow!("fleet manifest: bad base_seed: {e}"))?;
        let shards_j = j
            .get("shards")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("fleet manifest: missing 'shards' array"))?;
        let mut shards = Vec::with_capacity(shards_j.len());
        for (k, sj) in shards_j.iter().enumerate() {
            let scen_j = sj
                .get("scenarios")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("fleet manifest: shard {k} missing 'scenarios'"))?;
            let scenarios = scen_j
                .iter()
                .map(ScenarioSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            for s in &scenarios {
                s.validate()?;
            }
            shards.push(ShardPlan {
                shard: sj.opt_u64("shard", k as u64) as usize,
                report: sj
                    .get("report")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("fleet manifest: shard {k} missing 'report'"))?
                    .to_string(),
                scenarios,
            });
        }
        Ok(ShardManifest {
            base_seed,
            seeds: j
                .get("seeds")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("fleet manifest: missing 'seeds'"))?,
            smoke: j.opt_bool("smoke", false),
            jobs_override: j.get("jobs_override").and_then(Json::as_u64).map(|v| v as usize),
            shards,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::market::SpotModel;
    use crate::scenario::{MarketSpec, PolicySetSpec, WorkloadSpec};

    fn spec(name: &str) -> ScenarioSpec {
        ScenarioSpec {
            name: name.into(),
            description: String::new(),
            market: MarketSpec::single(SpotModel::paper_default(), 1.0),
            workload: WorkloadSpec::uniform(2),
            pool_capacity: 0,
            policy_set: PolicySetSpec::Auto,
            jobs: 40,
            tags: Vec::new(),
            migration: crate::policy::routing::MigrationPolicy::disabled(),
        }
    }

    #[test]
    fn round_robin_plan_covers_every_world_once() {
        let specs: Vec<ScenarioSpec> =
            ["a", "b", "c", "d", "e"].iter().map(|n| spec(n)).collect();
        let m = ShardManifest::plan(&specs, 3, 2, 7, true, Some(16)).unwrap();
        assert_eq!(m.shards.len(), 3);
        assert_eq!(m.worlds(), 5);
        let names: Vec<&str> = m
            .shards
            .iter()
            .flat_map(|s| s.scenarios.iter().map(|x| x.name.as_str()))
            .collect();
        let mut sorted = names.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec!["a", "b", "c", "d", "e"]);
        assert_eq!(m.shards[0].scenarios.len(), 2); // a, d
        assert_eq!(m.shards[2].scenarios.len(), 1); // c
        // More shards than worlds clamps.
        let m = ShardManifest::plan(&specs[..2], 8, 1, 7, false, None).unwrap();
        assert_eq!(m.shards.len(), 2);
    }

    #[test]
    fn manifest_roundtrips_and_validates() {
        let specs = vec![spec("a"), spec("b")];
        let m = ShardManifest::plan(&specs, 2, 3, 11, false, None).unwrap();
        let j = m.to_json();
        let back = ShardManifest::from_json(&j).unwrap();
        assert_eq!(back, m);
        assert!(!j.pretty().contains("jobs_override"), "None stays off-disk");
        // Duplicate scenario names refuse to plan.
        let err = ShardManifest::plan(&[spec("a"), spec("a")], 2, 1, 7, false, None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("duplicate scenario name"), "{err}");
        // Wrong schema refused.
        let mut bad = m.to_json();
        bad.set("schema", Json::Str("nope".into()));
        assert!(ShardManifest::from_json(&bad).is_err());
    }
}
