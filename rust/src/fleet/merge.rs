//! Associative, order-independent merging of shard reports into the
//! `dagcloud.fleet/v1` document.
//!
//! A shard report is an ordinary `dagcloud.scenarios/v1` document (the
//! schema was kept aggregation-friendly for exactly this): its detail rows
//! are keyed by `(scenario, replicate)` and round-trip losslessly through
//! [`crate::scenario::outcomes_from_report`]. The merge is therefore a
//! *set union of rows* followed by a canonical renormalization:
//!
//! 1. every absorbed row lands in one flat pool (duplicate cells are a
//!    hard error — a cell must be run exactly once across the fleet);
//! 2. at report time the pool is sorted by `(scenario, replicate)`;
//! 3. aggregates, robustness scores, and the document itself are
//!    recomputed from the sorted pool.
//!
//! Because steps 2–3 are pure functions of the row *set*, the fleet
//! report's bytes cannot depend on how the cells were sharded, which
//! shard finished first, or the order `absorb` was called in — the
//! property `rust/tests/integration_fleet.rs` pins against arbitrary
//! partitions and merge orders. (Float folds are order-sensitive in
//! general; fixing the fold order via the canonical sort is what turns
//! "equal up to reassociation" into "byte-identical".)
//!
//! The same accumulator also merges [`OnlineSnapshot`] streams from
//! `coordinator::online` runs (or their serialized `dagcloud.feed/v1`
//! reports) into one fleet-wide convergence timeline, sorted on
//! `(sim_time, source)` with a cumulative fleet job count.
//!
//! [`merge_health`] follows the same shape for `dagcloud.health/v1`
//! sections: duplicate sources are a hard error, the document is
//! recomputed from the sorted section set, so health bytes are
//! independent of shard plan and merge order too.

use std::collections::BTreeSet;

use anyhow::{anyhow, bail, ensure, Result};

use crate::coordinator::OnlineSnapshot;
use crate::scenario::{
    outcomes_from_report, scenario_sections_json, ReportMeta, ScenarioOutcome,
};
use crate::telemetry::health::{health_doc, HealthSection};
use crate::util::json::Json;

use super::robustness;

/// Accumulates shard reports; order of absorption never matters.
#[derive(Debug, Default)]
pub struct FleetAccumulator {
    meta: Option<ReportMeta>,
    outcomes: Vec<ScenarioOutcome>,
    seen: BTreeSet<(String, u64)>,
}

impl FleetAccumulator {
    pub fn new() -> FleetAccumulator {
        FleetAccumulator::default()
    }

    /// Absorb one `dagcloud.scenarios/v1` shard document. Errors on schema
    /// mismatch, metadata (seed count / base seed / smoke) disagreement
    /// with previously absorbed shards, or a `(scenario, replicate)` cell
    /// that some shard already contributed.
    pub fn absorb(&mut self, doc: &Json) -> Result<()> {
        let (rows, meta) = outcomes_from_report(doc)?;
        match self.meta {
            None => self.meta = Some(meta),
            Some(m) => ensure!(
                m == meta,
                "shard metadata mismatch: fleet has (seeds {}, base_seed {}, smoke {}), \
                 shard has (seeds {}, base_seed {}, smoke {})",
                m.seeds,
                m.base_seed,
                m.smoke,
                meta.seeds,
                meta.base_seed,
                meta.smoke
            ),
        }
        for row in rows {
            let key = (row.scenario.clone(), row.replicate);
            ensure!(
                self.seen.insert(key),
                "duplicate fleet cell ('{}', replicate {}): a scenario×seed cell must be \
                 run by exactly one shard",
                row.scenario,
                row.replicate
            );
            self.outcomes.push(row);
        }
        Ok(())
    }

    /// Cells absorbed so far.
    pub fn len(&self) -> usize {
        self.outcomes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.outcomes.is_empty()
    }

    /// The absorbed rows in canonical `(scenario, replicate)` order.
    pub fn canonical_outcomes(&self) -> Vec<ScenarioOutcome> {
        let mut sorted = self.outcomes.clone();
        sorted.sort_by(|a, b| {
            a.scenario
                .cmp(&b.scenario)
                .then(a.replicate.cmp(&b.replicate))
        });
        sorted
    }

    /// Emit the merged `dagcloud.fleet/v1` document. Pass the fleet's
    /// merged online timeline (if any coordinators streamed) to embed it
    /// under `online`.
    pub fn fleet_json(&self, online: Option<&MergedOnline>) -> Result<Json> {
        let meta = self
            .meta
            .ok_or_else(|| anyhow!("fleet merge: no shard reports absorbed"))?;
        let sorted = self.canonical_outcomes();
        let rob = robustness::score(&sorted);
        let worlds: BTreeSet<&str> = sorted.iter().map(|o| o.scenario.as_str()).collect();
        let mut j = Json::obj();
        j.set("schema", Json::Str("dagcloud.fleet/v1".into()))
            .set("seeds", Json::Num(meta.seeds as f64))
            .set("base_seed", Json::Str(meta.base_seed.to_string()))
            .set("smoke", Json::Bool(meta.smoke))
            .set("cells", Json::Num(sorted.len() as f64))
            .set("worlds", Json::Num(worlds.len() as f64))
            .set("scenarios", scenario_sections_json(&sorted))
            .set("robustness", robustness::robustness_json(&rob));
        if let Some(ol) = online {
            if !ol.points.is_empty() {
                j.set("online", ol.to_json());
            }
        }
        Ok(j)
    }
}

/// One coordinator's snapshot stream, tagged with a unique source label.
#[derive(Debug, Clone)]
pub struct OnlineSource {
    pub source: String,
    pub snapshots: Vec<OnlineSnapshot>,
}

/// One point of the merged fleet timeline.
#[derive(Debug, Clone)]
pub struct MergedOnlinePoint {
    pub source: String,
    pub sim_time: f64,
    /// Source-local jobs retired at this snapshot.
    pub jobs: u64,
    /// Fleet-wide jobs retired by this simulated time: the sum of each
    /// source's latest snapshot at or before this point.
    pub fleet_jobs: u64,
    /// Source-local feed frontier (slots ingested on every feed).
    pub ingested_slots: usize,
    pub average_unit_cost: f64,
    pub average_regret: f64,
    pub regret_bound: f64,
    pub max_weight: f64,
}

/// The merged fleet convergence timeline.
#[derive(Debug, Clone, Default)]
pub struct MergedOnline {
    /// Source labels in canonical (sorted) order.
    pub sources: Vec<String>,
    /// Points sorted by `(sim_time, source, jobs)`.
    pub points: Vec<MergedOnlinePoint>,
    /// Total jobs retired across all sources.
    pub total_jobs: u64,
}

impl MergedOnline {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set(
            "sources",
            Json::Arr(self.sources.iter().map(|s| Json::Str(s.clone())).collect()),
        )
        .set("total_jobs", Json::Num(self.total_jobs as f64))
        .set(
            "snapshots",
            Json::Arr(
                self.points
                    .iter()
                    .map(|p| {
                        let mut pj = Json::obj();
                        pj.set("source", Json::Str(p.source.clone()))
                            .set("sim_time", Json::Num(p.sim_time))
                            .set("jobs", Json::Num(p.jobs as f64))
                            .set("fleet_jobs", Json::Num(p.fleet_jobs as f64))
                            .set("ingested_slots", Json::Num(p.ingested_slots as f64))
                            .set("average_unit_cost", Json::Num(p.average_unit_cost))
                            .set("average_regret", Json::Num(p.average_regret))
                            .set("regret_bound", Json::Num(p.regret_bound))
                            .set("max_weight", Json::Num(p.max_weight));
                        pj
                    })
                    .collect(),
            ),
        );
        j
    }
}

/// Merge snapshot streams from many coordinators into one timeline.
///
/// Sources must carry distinct labels and time-ordered snapshots (what
/// [`crate::coordinator::tola_run_online`] emits). The merged order —
/// `(sim_time, source, jobs)`, ties broken by the label — is a total
/// order over the union, so the result is independent of the order the
/// sources are passed in.
pub fn merge_online(sources: &[OnlineSource]) -> Result<MergedOnline> {
    let mut labels: Vec<&str> = sources.iter().map(|s| s.source.as_str()).collect();
    labels.sort_unstable();
    for w in labels.windows(2) {
        ensure!(
            w[0] != w[1],
            "online merge: duplicate source label '{}'",
            w[0]
        );
    }
    for s in sources {
        ensure!(
            s.snapshots
                .windows(2)
                .all(|w| w[0].sim_time <= w[1].sim_time && w[0].jobs <= w[1].jobs),
            "online merge: source '{}' snapshots are not time-ordered",
            s.source
        );
    }
    let mut tagged: Vec<(&str, &OnlineSnapshot)> = sources
        .iter()
        .flat_map(|s| s.snapshots.iter().map(move |snap| (s.source.as_str(), snap)))
        .collect();
    tagged.sort_by(|(sa, a), (sb, b)| {
        a.sim_time
            .total_cmp(&b.sim_time)
            .then(sa.cmp(sb))
            .then(a.jobs.cmp(&b.jobs))
    });

    // Walk the merged order accumulating each source's latest job count.
    let mut last: std::collections::BTreeMap<&str, u64> = std::collections::BTreeMap::new();
    let mut points = Vec::with_capacity(tagged.len());
    for (src, snap) in tagged {
        last.insert(src, snap.jobs);
        points.push(MergedOnlinePoint {
            source: src.to_string(),
            sim_time: snap.sim_time,
            jobs: snap.jobs,
            fleet_jobs: last.values().sum(),
            ingested_slots: snap.ingested_slots,
            average_unit_cost: snap.average_unit_cost,
            average_regret: snap.average_regret,
            regret_bound: snap.regret_bound,
            max_weight: snap.max_weight,
        });
    }
    let total_jobs = sources
        .iter()
        .map(|s| s.snapshots.last().map(|x| x.jobs).unwrap_or(0))
        .sum();
    Ok(MergedOnline {
        sources: labels.into_iter().map(String::from).collect(),
        points,
        total_jobs,
    })
}

/// Parse a `dagcloud.feed/v1` document (what `repro feed` writes) into an
/// [`OnlineSource`] so separately-run coordinators merge into the fleet
/// report. The snapshot rows carry no policy index, so `best_policy` is
/// not reconstructed (the merged timeline does not use it).
pub fn online_source_from_feed_report(doc: &Json, source: &str) -> Result<OnlineSource> {
    let schema = doc.opt_str("schema", "");
    ensure!(
        schema == "dagcloud.feed/v1",
        "online source '{source}': expected schema dagcloud.feed/v1, found '{schema}'"
    );
    let arr = doc
        .get("snapshots")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("online source '{source}': missing 'snapshots' array"))?;
    let mut snapshots = Vec::with_capacity(arr.len());
    for (i, s) in arr.iter().enumerate() {
        let num = |key: &str| -> Result<f64> {
            s.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("online source '{source}': snapshot {i} missing '{key}'"))
        };
        snapshots.push(OnlineSnapshot {
            jobs: num("jobs")? as u64,
            sim_time: num("sim_time")?,
            ingested_slots: num("ingested_slots")? as usize,
            average_unit_cost: num("average_unit_cost")?,
            average_regret: num("average_regret")?,
            regret_bound: num("regret_bound")?,
            max_weight: num("max_weight")?,
            best_policy: 0,
        });
    }
    if snapshots.is_empty() {
        bail!("online source '{source}': no snapshots to merge");
    }
    Ok(OnlineSource {
        source: source.to_string(),
        snapshots,
    })
}

/// Merge folded health sections from many shards into one
/// `dagcloud.health/v1` document — the health-plane analogue of
/// [`merge_online`]. Each section is a pure function of one cell's event
/// log, so the merge is a set union: duplicate sources are a hard error
/// (a cell folds exactly once across the fleet) and the document is
/// recomputed from the source-sorted set, making the bytes independent of
/// partition and absorption order.
pub fn merge_health(sections: &[HealthSection]) -> Result<Json> {
    let mut sources: Vec<&str> = sections.iter().map(|s| s.source.as_str()).collect();
    sources.sort_unstable();
    for w in sources.windows(2) {
        ensure!(
            w[0] != w[1],
            "health merge: duplicate source '{}' (a cell folds exactly once)",
            w[0]
        );
    }
    Ok(health_doc(sections))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::report_json;

    fn outcome(name: &str, rep: u64, alpha: f64) -> ScenarioOutcome {
        ScenarioOutcome {
            scenario: name.into(),
            replicate: rep,
            run_seed: 100 + rep,
            jobs: 10,
            average_unit_cost: alpha,
            average_regret: 0.01,
            regret_bound: 0.5,
            pool_utilization: 0.0,
            so_share: 0.0,
            spot_share: 0.8,
            od_share: 0.2,
            availability_lo: 0.4,
            availability_hi: 0.9,
            best_policy: "p1".into(),
            offer_shares: Vec::new(),
            policy_costs: vec![("p1".into(), alpha), ("p2".into(), alpha + 0.1)],
            tags: Vec::new(),
            optimism_gap: Vec::new(),
            migrations: 0,
        }
    }

    fn snap(jobs: u64, t: f64) -> OnlineSnapshot {
        OnlineSnapshot {
            jobs,
            sim_time: t,
            ingested_slots: (t * 16.0) as usize,
            average_unit_cost: 0.3,
            average_regret: 0.02,
            regret_bound: 0.4,
            max_weight: 0.2,
            best_policy: 0,
        }
    }

    #[test]
    fn two_shards_merge_to_the_single_shard_bytes() {
        let all = vec![
            outcome("a", 0, 0.2),
            outcome("a", 1, 0.25),
            outcome("b", 0, 0.4),
        ];
        let single = {
            let mut acc = FleetAccumulator::new();
            acc.absorb(&report_json(&all, 2, 7, true)).unwrap();
            acc.fleet_json(None).unwrap().pretty()
        };
        let sharded = {
            let mut acc = FleetAccumulator::new();
            // Split mid-scenario and absorb in reverse order.
            acc.absorb(&report_json(&all[2..], 2, 7, true)).unwrap();
            acc.absorb(&report_json(&all[..2], 2, 7, true)).unwrap();
            acc.fleet_json(None).unwrap().pretty()
        };
        assert_eq!(single, sharded);
        let j = Json::parse(&single).unwrap();
        assert_eq!(j.get("schema").unwrap().as_str().unwrap(), "dagcloud.fleet/v1");
        assert_eq!(j.get("cells").unwrap().as_u64().unwrap(), 3);
        assert_eq!(j.get("worlds").unwrap().as_u64().unwrap(), 2);
        assert!(j.get("robustness").unwrap().get("policies").is_some());
    }

    #[test]
    fn duplicate_cells_and_meta_mismatch_error() {
        let rows = vec![outcome("a", 0, 0.2)];
        let mut acc = FleetAccumulator::new();
        acc.absorb(&report_json(&rows, 1, 7, true)).unwrap();
        let err = acc
            .absorb(&report_json(&rows, 1, 7, true))
            .unwrap_err()
            .to_string();
        assert!(err.contains("duplicate fleet cell"), "{err}");

        let mut acc = FleetAccumulator::new();
        acc.absorb(&report_json(&rows, 1, 7, true)).unwrap();
        let other = vec![outcome("b", 0, 0.2)];
        let err = acc
            .absorb(&report_json(&other, 1, 8, true))
            .unwrap_err()
            .to_string();
        assert!(err.contains("metadata mismatch"), "{err}");

        assert!(FleetAccumulator::new().fleet_json(None).is_err());
    }

    #[test]
    fn online_merge_is_source_order_independent_and_cumulative() {
        let a = OnlineSource {
            source: "coord-a".into(),
            snapshots: vec![snap(4, 1.0), snap(8, 2.0)],
        };
        let b = OnlineSource {
            source: "coord-b".into(),
            snapshots: vec![snap(5, 1.5), snap(9, 2.5)],
        };
        let ab = merge_online(&[a.clone(), b.clone()]).unwrap();
        let ba = merge_online(&[b, a]).unwrap();
        assert_eq!(ab.to_json().pretty(), ba.to_json().pretty());
        assert_eq!(ab.total_jobs, 17);
        let fleet: Vec<u64> = ab.points.iter().map(|p| p.fleet_jobs).collect();
        assert_eq!(fleet, vec![4, 9, 13, 17]);
        // Tie on sim_time breaks by label, deterministically.
        let t1 = OnlineSource {
            source: "x".into(),
            snapshots: vec![snap(1, 1.0)],
        };
        let t2 = OnlineSource {
            source: "y".into(),
            snapshots: vec![snap(2, 1.0)],
        };
        let m = merge_online(&[t2.clone(), t1.clone()]).unwrap();
        assert_eq!(m.points[0].source, "x");
        // Duplicate labels are refused.
        let err = merge_online(&[t1.clone(), t1]).unwrap_err().to_string();
        assert!(err.contains("duplicate source"), "{err}");
    }

    #[test]
    fn feed_report_parses_into_an_online_source() {
        let mut doc = Json::obj();
        doc.set("schema", Json::Str("dagcloud.feed/v1".into())).set(
            "snapshots",
            Json::Arr(vec![{
                let mut s = Json::obj();
                s.set("jobs", Json::Num(6.0))
                    .set("sim_time", Json::Num(3.5))
                    .set("ingested_slots", Json::Num(56.0))
                    .set("average_unit_cost", Json::Num(0.31))
                    .set("average_regret", Json::Num(0.02))
                    .set("regret_bound", Json::Num(0.4))
                    .set("max_weight", Json::Num(0.11));
                s
            }]),
        );
        let src = online_source_from_feed_report(&doc, "results/feed_run.json").unwrap();
        assert_eq!(src.snapshots.len(), 1);
        assert_eq!(src.snapshots[0].jobs, 6);
        assert_eq!(src.snapshots[0].ingested_slots, 56);
        // Wrong schema refused.
        doc.set("schema", Json::Str("dagcloud.scenarios/v1".into()));
        assert!(online_source_from_feed_report(&doc, "x").is_err());
    }

    #[test]
    fn health_merge_is_order_independent_and_refuses_duplicates() {
        use crate::telemetry::health::fold_events;
        use crate::telemetry::{SimEvent, SimEventKind};
        let row = |src: &str, t: f64, seq: u64| {
            SimEvent { sim_time: t, seq, kind: SimEventKind::FrontierAdvanced { slots: 12 } }
                .to_json(src)
        };
        let a = fold_events(&[row("a#0", 1.0, 0)]);
        let b = fold_events(&[row("b#0", 2.0, 0)]);
        let mut ab = a.clone();
        ab.extend(b.clone());
        let mut ba = b.clone();
        ba.extend(a.clone());
        assert_eq!(
            merge_health(&ab).unwrap().pretty(),
            merge_health(&ba).unwrap().pretty()
        );
        let mut dup = a.clone();
        dup.extend(a);
        let err = merge_health(&dup).unwrap_err().to_string();
        assert!(err.contains("duplicate source"), "{err}");
    }
}
