//! The paper's evaluation metrics (§6.1 "Performance Metric").
//!
//! * `α_{x1,x2}(π)` — average unit cost of processing all jobs under policy
//!   π with `x1` self-owned instances on job type `x2`;
//! * `α` / `α'` — minimum over the proposed / benchmark policy sets;
//! * `ρ = 1 − α/α'` — cost improvement;
//! * `μ` — ratio of self-owned utilization, proposed over benchmark.

use super::horizon::HorizonReport;

/// Minimum average unit cost over a set of per-policy reports
/// (`α = min_π α(π)`); returns the index of the winning policy too.
pub fn min_unit_cost(reports: &[HorizonReport]) -> (f64, usize) {
    assert!(!reports.is_empty());
    let mut best = f64::INFINITY;
    let mut idx = 0;
    for (i, r) in reports.iter().enumerate() {
        let a = r.average_unit_cost();
        if a < best {
            best = a;
            idx = i;
        }
    }
    (best, idx)
}

/// Cost improvement `ρ = 1 − α / α'` of the proposed `α` over the benchmark
/// `α'`.
pub fn cost_improvement(alpha_proposed: f64, alpha_benchmark: f64) -> f64 {
    if alpha_benchmark <= 0.0 {
        return 0.0;
    }
    1.0 - alpha_proposed / alpha_benchmark
}

/// Utilization ratio `μ` = proposed self-owned utilization over benchmark's.
pub fn utilization_ratio(proposed: &HorizonReport, benchmark: &HorizonReport) -> f64 {
    if benchmark.pool_utilization <= 0.0 {
        return if proposed.pool_utilization <= 0.0 { 1.0 } else { f64::INFINITY };
    }
    proposed.pool_utilization / benchmark.pool_utilization
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::market::CostLedger;

    fn report(cost: f64, work: f64, util: f64) -> HorizonReport {
        let mut ledger = CostLedger::new();
        ledger.cost_ondemand = cost;
        ledger.work_ondemand = work;
        HorizonReport {
            strategy: "t".into(),
            jobs: 1,
            ledger,
            total_workload: work,
            job_costs: vec![cost],
            deadlines_met: 1,
            pool_utilization: util,
            selfowned_work: 0.0,
        }
    }

    #[test]
    fn min_unit_cost_picks_cheapest() {
        let reports = vec![report(10.0, 10.0, 0.0), report(5.0, 10.0, 0.0), report(8.0, 10.0, 0.0)];
        let (a, i) = min_unit_cost(&reports);
        assert_eq!(i, 1);
        assert!((a - 0.5).abs() < 1e-12);
    }

    #[test]
    fn rho_definition() {
        assert!((cost_improvement(0.5, 1.0) - 0.5).abs() < 1e-12);
        assert_eq!(cost_improvement(1.0, 1.0), 0.0);
        assert_eq!(cost_improvement(1.0, 0.0), 0.0);
    }

    #[test]
    fn mu_definition() {
        let p = report(1.0, 1.0, 0.6);
        let b = report(1.0, 1.0, 0.8);
        assert!((utilization_ratio(&p, &b) - 0.75).abs() < 1e-12);
        let z = report(1.0, 1.0, 0.0);
        assert_eq!(utilization_ratio(&z, &z), 1.0);
    }
}
