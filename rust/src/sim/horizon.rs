//! Full-horizon simulation: a stream of chain jobs processed under one
//! strategy, with a *shared* self-owned pool.
//!
//! Pool contention across concurrent jobs is resolved in event order: a
//! task's self-owned grant happens at its realized start time, so tasks of
//! different jobs interleave exactly as the coordinator of Algorithm 2
//! would process them ("we check whether specific events are triggered at
//! every moment t").

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::executor::{execute_chain, execute_task, ChainStrategy, JobOutcome, SelfOwnedRule, TaskOutcome};
use crate::market::{CostLedger, InstanceKind, PriceTrace, SelfOwnedPool, SLOTS_PER_UNIT};
use crate::policy::baselines::even_windows;
use crate::policy::dealloc::{dealloc, windows_to_deadlines};
use crate::policy::selfowned::{naive_allocation, rule12};
use crate::policy::Policy;
use crate::workload::ChainJob;

/// A complete strategy for a horizon run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StrategySpec {
    /// The paper's framework: Dealloc windows (Algorithm 2 lines 1–5),
    /// rule (12) for self-owned instances, Def. 3.1/3.2 inside windows.
    Proposed(Policy),
    /// Even windows + naive self-owned (the §6.1 benchmark combination).
    EvenBaseline { bid: f64 },
    /// Dealloc windows + naive self-owned (isolates rule (12); used by
    /// Experiment 3 where both sides share the deadline allocation).
    DeallocNaive(Policy),
    /// The Greedy baseline (spot+OD only).
    GreedyBaseline { bid: f64 },
}

impl StrategySpec {
    pub fn bid(&self) -> f64 {
        match self {
            StrategySpec::Proposed(p) | StrategySpec::DeallocNaive(p) => p.bid,
            StrategySpec::EvenBaseline { bid } | StrategySpec::GreedyBaseline { bid } => *bid,
        }
    }

    pub fn label(&self) -> String {
        match self {
            StrategySpec::Proposed(p) => format!(
                "proposed(β={:.3},β₀={},b={:.2})",
                p.beta,
                p.beta0.map(|x| format!("{x:.3}")).unwrap_or("-".into()),
                p.bid
            ),
            StrategySpec::EvenBaseline { bid } => format!("even(b={bid:.2})"),
            StrategySpec::DeallocNaive(p) => {
                format!("dealloc+naive(β={:.3},b={:.2})", p.beta, p.bid)
            }
            StrategySpec::GreedyBaseline { bid } => format!("greedy(b={bid:.2})"),
        }
    }
}

/// Aggregated result of a horizon run.
#[derive(Debug, Clone)]
pub struct HorizonReport {
    pub strategy: String,
    pub jobs: usize,
    pub ledger: CostLedger,
    /// Total workload Σ_j Z_j.
    pub total_workload: f64,
    /// Per-job cost c_j (indexed as the input job order).
    pub job_costs: Vec<f64>,
    /// Per-job deadline compliance.
    pub deadlines_met: usize,
    /// Self-owned pool utilization: *reserved* instance-time over
    /// capacity·horizon. Reserved (not processed) time is the paper's
    /// Table-5 notion — the naive rule over-reserves, which is exactly why
    /// it shows higher utilization yet higher cost.
    pub pool_utilization: f64,
    /// Self-owned *processed* workload.
    pub selfowned_work: f64,
}

impl HorizonReport {
    /// The paper's average unit cost `α = Σ c_j / Σ Z_j`.
    pub fn average_unit_cost(&self) -> f64 {
        if self.total_workload == 0.0 {
            0.0
        } else {
            self.ledger.total_cost() / self.total_workload
        }
    }
}

/// Min-heap event key.
#[derive(Debug, PartialEq)]
struct Event {
    time: f64,
    job: usize,
    task: usize,
}

impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for a min-heap on time; break ties by (job, task) for
        // determinism.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap()
            .then(other.job.cmp(&self.job))
            .then(other.task.cmp(&self.task))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Runs a set of chain jobs (sorted or not by arrival) under one strategy.
pub struct HorizonRunner<'a> {
    pub trace: &'a PriceTrace,
    pub od_price: f64,
    /// Self-owned pool capacity (0 = no pool).
    pub pool_capacity: u32,
}

impl<'a> HorizonRunner<'a> {
    pub fn new(trace: &'a PriceTrace, pool_capacity: u32) -> Self {
        HorizonRunner {
            trace,
            od_price: crate::market::ON_DEMAND_PRICE,
            pool_capacity,
        }
    }

    /// Execute all jobs under `spec`, returning the aggregate report.
    pub fn run(&self, jobs: &[ChainJob], spec: StrategySpec) -> HorizonReport {
        let horizon = jobs
            .iter()
            .map(|j| j.deadline)
            .fold(0.0, f64::max)
            .max(1.0);
        let mut pool = (self.pool_capacity > 0)
            .then(|| SelfOwnedPool::new(self.pool_capacity, horizon, 1.0 / SLOTS_PER_UNIT as f64));

        // Greedy runs have no pool interaction: execute per job directly.
        if let StrategySpec::GreedyBaseline { bid } = spec {
            return self.aggregate(
                jobs,
                spec,
                jobs.iter()
                    .map(|job| {
                        execute_chain(
                            job,
                            &ChainStrategy::Greedy { bid },
                            self.trace,
                            None,
                            self.od_price,
                        )
                    })
                    .collect(),
                pool.as_ref(),
                horizon,
            );
        }

        // Precompute windows/deadlines per job at its arrival.
        let has_pool = pool.is_some();
        let per_job: Vec<(Vec<f64>, Vec<f64>)> = jobs
            .iter()
            .map(|job| {
                let windows = match spec {
                    StrategySpec::Proposed(p) | StrategySpec::DeallocNaive(p) => {
                        dealloc(job, p.dealloc_beta(has_pool))
                    }
                    StrategySpec::EvenBaseline { .. } => even_windows(job),
                    StrategySpec::GreedyBaseline { .. } => unreachable!(),
                };
                let deadlines = windows_to_deadlines(job, &windows);
                (windows.sizes, deadlines)
            })
            .collect();

        // The spec is fixed for the whole run, so the self-owned rule is
        // resolved once here and drives the per-task grant below.
        let so_rule = match (has_pool, spec) {
            (false, _) => SelfOwnedRule::None,
            (true, StrategySpec::Proposed(p)) => match p.beta0 {
                Some(beta0) => SelfOwnedRule::Rule12 { beta0 },
                None => SelfOwnedRule::None,
            },
            (true, _) => SelfOwnedRule::Naive,
        };

        // Event-ordered execution.
        let mut heap = BinaryHeap::new();
        for (idx, job) in jobs.iter().enumerate() {
            heap.push(Event {
                time: job.arrival,
                job: idx,
                task: 0,
            });
        }
        let mut outcomes: Vec<JobOutcome> = jobs
            .iter()
            .map(|job| JobOutcome {
                job_id: job.id,
                ledger: CostLedger::new(),
                tasks: Vec::new(),
                finish: job.arrival,
                met_deadline: true,
            })
            .collect();

        while let Some(Event { time, job: ji, task: ti }) = heap.pop() {
            let job = &jobs[ji];
            if ti >= job.num_tasks() {
                outcomes[ji].finish = time;
                outcomes[ji].met_deadline = time <= job.deadline + 1e-6;
                continue;
            }
            let t = &job.tasks[ti];
            let deadline = per_job[ji].1[ti].max(time);
            let start = time.min(deadline);
            let hat_s = (deadline - start).max(1e-12);
            let r = match (&mut pool, so_rule) {
                (None, _) | (_, SelfOwnedRule::None) => 0,
                (Some(pl), SelfOwnedRule::Rule12 { beta0 }) => {
                    let n = pl.available_over(start, deadline);
                    let r = rule12(t.size, t.parallelism, hat_s, beta0, n);
                    pl.reserve(r, start, deadline);
                    r
                }
                (Some(pl), SelfOwnedRule::Naive) => {
                    let n = pl.available_over(start, deadline);
                    let r = naive_allocation(t.parallelism, n);
                    pl.reserve(r, start, deadline);
                    r
                }
            };
            let out: TaskOutcome = execute_task(
                t.size,
                t.parallelism,
                start,
                deadline,
                r,
                spec.bid(),
                self.trace,
                self.od_price,
            );
            let ledger = &mut outcomes[ji].ledger;
            ledger.charge(InstanceKind::SelfOwned, 1.0, out.so_work, 0.0);
            ledger.charge(InstanceKind::Spot, 1.0, out.spot_work, 0.0);
            ledger.cost_spot += out.spot_cost;
            ledger.charge(InstanceKind::OnDemand, 1.0, out.od_work, 0.0);
            ledger.cost_ondemand += out.od_cost;
            let finish = out.finish;
            outcomes[ji].tasks.push(out);
            heap.push(Event {
                time: finish,
                job: ji,
                task: ti + 1,
            });
        }

        self.aggregate(jobs, spec, outcomes, pool.as_ref(), horizon)
    }

    fn aggregate(
        &self,
        jobs: &[ChainJob],
        spec: StrategySpec,
        outcomes: Vec<JobOutcome>,
        pool: Option<&SelfOwnedPool>,
        horizon: f64,
    ) -> HorizonReport {
        let mut ledger = CostLedger::new();
        let mut job_costs = Vec::with_capacity(outcomes.len());
        let mut met = 0usize;
        for o in &outcomes {
            ledger.merge(&o.ledger);
            job_costs.push(o.cost());
            met += o.met_deadline as usize;
        }
        let selfowned_work = ledger.work_selfowned;
        let pool_utilization = match pool {
            Some(p) if self.pool_capacity > 0 => {
                p.reserved_instance_time() / (self.pool_capacity as f64 * horizon)
            }
            _ => 0.0,
        };
        HorizonReport {
            strategy: spec.label(),
            jobs: jobs.len(),
            total_workload: jobs.iter().map(|j| j.total_work()).sum(),
            ledger,
            job_costs,
            deadlines_met: met,
            pool_utilization,
            selfowned_work,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::market::SpotModel;
    use crate::workload::{transform, GeneratorConfig, JobStream};

    fn chain_jobs(n: usize, seed: u64) -> Vec<ChainJob> {
        let mut stream = JobStream::new(GeneratorConfig::small(), seed);
        stream
            .take_jobs(n)
            .iter()
            .map(transform)
            .collect()
    }

    fn trace_for(jobs: &[ChainJob], seed: u64) -> PriceTrace {
        let horizon = jobs.iter().map(|j| j.deadline).fold(0.0, f64::max) + 1.0;
        PriceTrace::generate(SpotModel::paper_default(), horizon, seed)
    }

    #[test]
    fn all_strategies_meet_all_deadlines() {
        let jobs = chain_jobs(40, 1);
        let trace = trace_for(&jobs, 2);
        let runner = HorizonRunner::new(&trace, 0);
        for spec in [
            StrategySpec::Proposed(Policy::new(1.0 / 1.6, None, 0.24)),
            StrategySpec::EvenBaseline { bid: 0.24 },
            StrategySpec::GreedyBaseline { bid: 0.24 },
        ] {
            let rep = runner.run(&jobs, spec);
            assert_eq!(rep.deadlines_met, jobs.len(), "{}", rep.strategy);
            assert!((rep.ledger.total_work() - rep.total_workload).abs() < 1e-6 * rep.total_workload);
        }
    }

    #[test]
    fn proposed_beats_baselines_on_average() {
        let jobs = chain_jobs(150, 3);
        let trace = trace_for(&jobs, 4);
        let runner = HorizonRunner::new(&trace, 0);
        let prop = runner
            .run(&jobs, StrategySpec::Proposed(Policy::new(1.0 / 1.6, None, 0.24)))
            .average_unit_cost();
        let even = runner
            .run(&jobs, StrategySpec::EvenBaseline { bid: 0.24 })
            .average_unit_cost();
        let greedy = runner
            .run(&jobs, StrategySpec::GreedyBaseline { bid: 0.24 })
            .average_unit_cost();
        assert!(
            prop < even * 1.02,
            "proposed {prop} should not lose to even {even}"
        );
        assert!(
            prop < greedy * 1.02,
            "proposed {prop} should not lose to greedy {greedy}"
        );
    }

    #[test]
    fn pool_reduces_cost() {
        let jobs = chain_jobs(60, 5);
        let trace = trace_for(&jobs, 6);
        let p = Policy::new(1.0 / 1.6, Some(4.0 / 14.0), 0.24);
        let no_pool = HorizonRunner::new(&trace, 0)
            .run(&jobs, StrategySpec::Proposed(p))
            .average_unit_cost();
        let with_pool = HorizonRunner::new(&trace, 200)
            .run(&jobs, StrategySpec::Proposed(p))
            .average_unit_cost();
        assert!(
            with_pool < no_pool,
            "pool should cut cost: {with_pool} vs {no_pool}"
        );
    }

    #[test]
    fn naive_pool_utilization_at_least_rule12() {
        let jobs = chain_jobs(60, 7);
        let trace = trace_for(&jobs, 8);
        let p = Policy::new(1.0 / 1.6, Some(0.5), 0.24);
        let rule12_rep = HorizonRunner::new(&trace, 100).run(&jobs, StrategySpec::Proposed(p));
        let naive_rep = HorizonRunner::new(&trace, 100).run(&jobs, StrategySpec::DeallocNaive(p));
        assert!(
            naive_rep.selfowned_work >= rule12_rep.selfowned_work * 0.8,
            "naive {} vs rule12 {}",
            naive_rep.selfowned_work,
            rule12_rep.selfowned_work
        );
    }

    #[test]
    fn per_job_costs_sum_to_total() {
        let jobs = chain_jobs(30, 9);
        let trace = trace_for(&jobs, 10);
        let rep = HorizonRunner::new(&trace, 50)
            .run(&jobs, StrategySpec::Proposed(Policy::new(0.5, Some(0.5), 0.24)));
        let sum: f64 = rep.job_costs.iter().sum();
        assert!((sum - rep.ledger.total_cost()).abs() < 1e-6 * sum.max(1.0));
    }
}
