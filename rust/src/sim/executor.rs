//! Execution of chain jobs against a realized price trace.
//!
//! The executor follows Algorithm 2's event semantics exactly, in
//! continuous time with slot-piecewise-constant prices:
//!
//! * a task runs in `[ς̃_i, ς_i]` where `ς̃_i` is the realized finish of its
//!   predecessor (early finishes propagate) and `ς_i` its allocated
//!   deadline;
//! * it holds `r_i` self-owned instances for the whole window (rule (12) or
//!   the naive baseline), leaving `z̃ = z − r_i·ŝ` for spot/on-demand;
//! * while it *has flexibility* (Def. 3.1) it requests `δ−r` spot instances
//!   at bid `b`, paying the realized spot price for slots actually won;
//! * at the *turning point* (Def. 3.2) it switches to `δ−r` on-demand
//!   instances through its deadline.
//!
//! Within an unavailable slot the flexibility margin `(ς_i−t) − z̃/(δ−r)`
//! shrinks at unit rate, so the executor computes the exact in-slot turning
//! point rather than checking only at slot boundaries — matching the
//! paper's "at every moment" semantics and guaranteeing deadlines are met
//! exactly rather than overshot by quantization.

use crate::market::{CapacityLedger, CostLedger, InstanceKind, MarketView, PriceTrace, SelfOwnedPool};
use crate::policy::baselines::greedy_must_switch;
use crate::policy::dealloc::WindowAllocation;
use crate::policy::routing::{route, MigrationPolicy, RouteDecision, RoutingPolicy};
use crate::policy::selfowned::{naive_allocation, rule12};
use crate::workload::ChainJob;

const EPS: f64 = 1e-9;

/// How self-owned instances are granted per task.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SelfOwnedRule {
    /// No self-owned instances (r = 0).
    None,
    /// The paper's rule (12) with sufficiency index β₀.
    Rule12 { beta0: f64 },
    /// The §6.1 benchmark: grab `min(N, δ)`.
    Naive,
}

/// A complete per-job strategy.
#[derive(Debug, Clone)]
pub enum ChainStrategy<'a> {
    /// Pre-allocated windows (Dealloc or Even) + Def. 3.1/3.2 instance
    /// allocation inside each window.
    Windows {
        windows: &'a WindowAllocation,
        selfowned: SelfOwnedRule,
        bid: f64,
    },
    /// The Greedy baseline: all-spot for the current task until the
    /// remaining critical path meets the remaining window, then all
    /// on-demand. No self-owned instances (§6.1 applies it to spot+OD
    /// only).
    Greedy { bid: f64 },
}

/// Outcome of one task.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskOutcome {
    pub start: f64,
    pub deadline: f64,
    pub finish: f64,
    /// Self-owned instances held over `[start, deadline]`.
    pub r: u32,
    pub so_work: f64,
    pub spot_work: f64,
    pub od_work: f64,
    pub spot_cost: f64,
    pub od_cost: f64,
}

/// Outcome of a job.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub job_id: u64,
    pub ledger: CostLedger,
    pub tasks: Vec<TaskOutcome>,
    pub finish: f64,
    pub met_deadline: bool,
}

impl JobOutcome {
    pub fn cost(&self) -> f64 {
        self.ledger.total_cost()
    }
}

/// Execute one task in `[start, deadline]` with `r` self-owned instances
/// already granted, bidding `bid` for spot, falling back to on-demand at
/// `od_price` at the turning point.
pub fn execute_task(
    z: f64,
    delta: f64,
    start: f64,
    deadline: f64,
    r: u32,
    bid: f64,
    trace: &PriceTrace,
    od_price: f64,
) -> TaskOutcome {
    debug_assert!(deadline > start - EPS);
    let hat_s = (deadline - start).max(0.0);
    let delta_eff = delta - r as f64;
    let so_cap = r as f64 * hat_s;
    let so_work = z.min(so_cap);
    let mut zt = z - so_work; // z̃: workload for spot/on-demand

    let mut out = TaskOutcome {
        start,
        deadline,
        finish: start,
        r,
        so_work,
        spot_work: 0.0,
        od_work: 0.0,
        spot_cost: 0.0,
        od_cost: 0.0,
    };

    if zt <= EPS {
        // Self-owned covers everything; the instances are held through the
        // window, so the task completes at its deadline (if r > 0) or
        // immediately (degenerate z = 0).
        out.finish = if r > 0 { deadline } else { start };
        return out;
    }
    if delta_eff <= EPS {
        // No spot/on-demand headroom and work remains: infeasible input
        // (only possible for infeasible windows). Best effort: nothing else
        // to do, the task overruns.
        out.finish = deadline + zt; // sentinel overrun
        return out;
    }

    let dt = trace.slot_len();
    let mut t = start;
    loop {
        if zt <= EPS {
            // Spot/OD share finished; self-owned still holds to ς_i.
            out.finish = if r > 0 { deadline } else { t };
            break;
        }
        let time_left = deadline - t;
        if zt >= delta_eff * time_left - EPS {
            // Turning point (Def. 3.2): all on-demand through the deadline.
            out.od_work += zt;
            out.od_cost += od_price * zt;
            let od_finish = t + zt / delta_eff;
            out.finish = if r > 0 { deadline.max(od_finish) } else { od_finish };
            break;
        }
        // Next slot boundary strictly after t. Guard against fp division
        // rounding making the "next" boundary equal to t (k·dt / dt can
        // round down), which would stall the walk.
        let mut slot_end = ((t / dt).floor() + 1.0) * dt;
        while slot_end <= t {
            slot_end += dt;
        }
        let seg_end = slot_end.min(deadline);
        let price = trace.price_at(t + EPS);
        if price <= bid {
            // Winning slot: progress at δ−r; margin constant.
            let t_fin = t + zt / delta_eff;
            let upto = seg_end.min(t_fin);
            let dw = delta_eff * (upto - t);
            out.spot_work += dw;
            out.spot_cost += price * dw;
            zt -= dw;
            t = upto;
        } else {
            // Losing slot: no progress; margin shrinks at unit rate. The
            // in-slot turning point is at t_c = ς_i − z̃/(δ−r).
            let t_c = deadline - zt / delta_eff;
            t = if t_c <= seg_end + EPS { t_c.max(t) } else { seg_end };
        }
    }
    out
}

/// Spot instance units a task places on an offer: the whole `δ − r`
/// request, rounded up (capacity is counted in whole instances).
#[inline]
pub fn spot_units(delta: f64, r: u32) -> u32 {
    (delta - r as f64).max(0.0).ceil() as u32
}

/// Execute one task against a capacity-aware [`MarketView`]: route it,
/// reserve its spot units on the chosen offer, and run the Def. 3.1/3.2
/// walk against that offer's realized prices. Returns `(offer, outcome)`.
///
/// When no offer can hold the task's units the task runs all-on-demand on
/// the decision's fallback offer (`bid = −∞` disables every spot slot, so
/// the walk is the exact never-available case and the deadline still
/// holds). A one-offer infinite-capacity view reduces bit-identically to
/// [`execute_task`] on that offer's trace under every routing policy.
#[allow(clippy::too_many_arguments)]
pub fn execute_task_routed(
    z: f64,
    delta: f64,
    start: f64,
    deadline: f64,
    r: u32,
    bid: f64,
    view: &MarketView,
    cap: &mut CapacityLedger,
    routing: RoutingPolicy,
) -> (usize, TaskOutcome) {
    let (d, outcome) =
        execute_task_routed_decide(z, delta, start, deadline, r, bid, view, cap, routing);
    (d.offer, outcome)
}

/// [`execute_task_routed`], but returning the full [`RouteDecision`] so
/// instrumented callers can observe capacity exhaustion (the
/// `spot_capacity = false` all-on-demand fallback) instead of having the
/// bit dropped with the decision.
#[allow(clippy::too_many_arguments)]
pub fn execute_task_routed_decide(
    z: f64,
    delta: f64,
    start: f64,
    deadline: f64,
    r: u32,
    bid: f64,
    view: &MarketView,
    cap: &mut CapacityLedger,
    routing: RoutingPolicy,
) -> (RouteDecision, TaskOutcome) {
    let units = spot_units(delta, r);
    let d = route(routing, view, cap, units, start, deadline);
    let offer = &view.offers()[d.offer];
    if d.spot_capacity {
        let ok = cap.reserve(d.offer, units, start, deadline);
        debug_assert!(ok, "router approved an offer the ledger refused");
        (
            d,
            execute_task(z, delta, start, deadline, r, bid, &offer.trace, offer.od_price),
        )
    } else {
        (
            d,
            execute_task(
                z,
                delta,
                start,
                deadline,
                r,
                f64::NEG_INFINITY,
                &offer.trace,
                offer.od_price,
            ),
        )
    }
}

/// One mid-window migration taken by [`execute_task_routed_migrating`].
#[derive(Debug, Clone, PartialEq)]
pub struct MigrationRecord {
    /// Simulation time of the switch (the walk start or a slot boundary).
    pub time: f64,
    pub from_offer: usize,
    pub to_offer: usize,
    /// Projected saving over the remaining spot/on-demand workload that
    /// justified the switch (already net of nothing — the switch cost is
    /// charged separately into the task's spot cost).
    pub projected_saving: f64,
}

/// [`execute_task_routed_decide`] with slot-granular mid-window migration.
///
/// The task is routed and reserved exactly as in the pinned path; then the
/// Def. 3.1/3.2 walk runs with one added rule evaluated wherever the
/// cursor rests on a slot boundary (prices are slot-piecewise constant, so
/// boundaries are the only moments the comparison changes): if another
/// offer is winnable at the task's bid (`price <= bid`), can hold the
/// task's spot units through the deadline, and the projected saving over
/// the remaining workload `z̃` exceeds `migration.switch_cost`, the task
/// releases the unconsumed tail of its reservation, reserves on the new
/// offer, pays the switch cost (charged into `spot_cost`), and continues
/// on the new offer's trace. The saving is projected against the current
/// slot's price when this slot is winnable, else against the current
/// offer's on-demand price (the rate the remaining work would otherwise
/// degrade to). `hysteresis_slots` suppresses re-switching for that many
/// slots after a move. The turning point still degrades to on-demand on
/// the *current* offer — migration never trades away the deadline.
///
/// Callers must branch on [`MigrationPolicy::enabled`] and keep calling
/// [`execute_task_routed_decide`] when migration is off: the disabled
/// contract is structural (the legacy code path runs), not numerical.
///
/// Work attribution: `offer_work`-style callers should charge the *final*
/// offer (`records.last().to_offer`, falling back to the route decision);
/// a migrated task's per-offer work split is not tracked.
#[allow(clippy::too_many_arguments)]
pub fn execute_task_routed_migrating(
    z: f64,
    delta: f64,
    start: f64,
    deadline: f64,
    r: u32,
    bid: f64,
    view: &MarketView,
    cap: &mut CapacityLedger,
    routing: RoutingPolicy,
    migration: MigrationPolicy,
) -> (RouteDecision, TaskOutcome, Vec<MigrationRecord>) {
    let units = spot_units(delta, r);
    let d = route(routing, view, cap, units, start, deadline);
    if !d.spot_capacity {
        // Capacity exhausted everywhere at the start: the pinned path's
        // all-on-demand fallback, no migration (spot stays exhausted for
        // this window's units by the router's own check).
        let offer = &view.offers()[d.offer];
        let out = execute_task(
            z,
            delta,
            start,
            deadline,
            r,
            f64::NEG_INFINITY,
            &offer.trace,
            offer.od_price,
        );
        return (d, out, Vec::new());
    }
    let ok = cap.reserve(d.offer, units, start, deadline);
    debug_assert!(ok, "router approved an offer the ledger refused");

    debug_assert!(deadline > start - EPS);
    let hat_s = (deadline - start).max(0.0);
    let delta_eff = delta - r as f64;
    let so_cap = r as f64 * hat_s;
    let so_work = z.min(so_cap);
    let mut zt = z - so_work;

    let mut out = TaskOutcome {
        start,
        deadline,
        finish: start,
        r,
        so_work,
        spot_work: 0.0,
        od_work: 0.0,
        spot_cost: 0.0,
        od_cost: 0.0,
    };
    let mut records: Vec<MigrationRecord> = Vec::new();

    if zt <= EPS {
        out.finish = if r > 0 { deadline } else { start };
        return (d, out, records);
    }
    if delta_eff <= EPS {
        out.finish = deadline + zt;
        return (d, out, records);
    }

    let dt = view.slot_len();
    let mut cur = d.offer;
    let mut t = start;
    // First boundary at which a switch may be taken (hysteresis cursor).
    let mut next_eligible = start;
    loop {
        if zt <= EPS {
            out.finish = if r > 0 { deadline } else { t };
            break;
        }
        let time_left = deadline - t;
        if zt >= delta_eff * time_left - EPS {
            // Turning point: all on-demand on the current offer.
            let od_price = view.offers()[cur].od_price;
            out.od_work += zt;
            out.od_cost += od_price * zt;
            let od_finish = t + zt / delta_eff;
            out.finish = if r > 0 { deadline.max(od_finish) } else { od_finish };
            break;
        }
        if t + EPS >= next_eligible {
            let p_cur = view.offers()[cur].trace.price_at(t + EPS);
            // What the remaining work would pay here: this slot's spot
            // price if winnable, else the eventual on-demand degrade.
            let reference = if p_cur <= bid {
                p_cur
            } else {
                view.offers()[cur].od_price
            };
            let mut best: Option<(usize, f64)> = None;
            for (k, o) in view.offers().iter().enumerate() {
                if k == cur {
                    continue;
                }
                let p = o.trace.price_at(t + EPS);
                if p > bid || !cap.can_place(k, units, t, deadline) {
                    continue;
                }
                if best.map_or(true, |(_, bp)| p < bp) {
                    best = Some((k, p));
                }
            }
            if let Some((k, p_new)) = best {
                let saving = (reference - p_new) * zt;
                if saving > migration.switch_cost {
                    cap.release(cur, units, t, deadline);
                    let ok = cap.reserve(k, units, t, deadline);
                    debug_assert!(ok, "migration target lost capacity between check and reserve");
                    records.push(MigrationRecord {
                        time: t,
                        from_offer: cur,
                        to_offer: k,
                        projected_saving: saving,
                    });
                    out.spot_cost += migration.switch_cost;
                    cur = k;
                    next_eligible = t + migration.hysteresis_slots as f64 * dt;
                }
            }
        }
        // One slot step on the current offer — identical arithmetic to
        // [`execute_task`]'s walk.
        let mut slot_end = ((t / dt).floor() + 1.0) * dt;
        while slot_end <= t {
            slot_end += dt;
        }
        let seg_end = slot_end.min(deadline);
        let price = view.offers()[cur].trace.price_at(t + EPS);
        if price <= bid {
            let t_fin = t + zt / delta_eff;
            let upto = seg_end.min(t_fin);
            let dw = delta_eff * (upto - t);
            out.spot_work += dw;
            out.spot_cost += price * dw;
            zt -= dw;
            t = upto;
        } else {
            let t_c = deadline - zt / delta_eff;
            t = if t_c <= seg_end + EPS { t_c.max(t) } else { seg_end };
        }
    }
    (d, out, records)
}

/// A routed chain execution: the legacy outcome plus where each task ran.
#[derive(Debug, Clone)]
pub struct RoutedChainOutcome {
    pub outcome: JobOutcome,
    /// Offer index each task was placed on, in chain order.
    pub task_offers: Vec<usize>,
}

/// Execute a whole chain job against a [`MarketView`] under windows +
/// Def. 3.1/3.2 allocation, routing each task at its realized start.
/// The one-offer infinite-capacity case reproduces [`execute_chain`] with
/// a `Windows` strategy exactly (both run through the same private
/// `execute_windows_with` loop).
#[allow(clippy::too_many_arguments)]
pub fn execute_chain_routed(
    job: &ChainJob,
    windows: &WindowAllocation,
    selfowned: SelfOwnedRule,
    bid: f64,
    view: &MarketView,
    cap: &mut CapacityLedger,
    routing: RoutingPolicy,
    pool: Option<&mut SelfOwnedPool>,
) -> RoutedChainOutcome {
    execute_windows_with(job, windows, selfowned, pool, |z, delta, start, deadline, r| {
        execute_task_routed(z, delta, start, deadline, r, bid, view, cap, routing)
    })
}

/// The shared windows-execution loop: deadline cursor, per-task self-owned
/// grant, ledger charging — parameterized by how one task actually runs
/// (legacy single-trace vs routed). Both public entry points are thin
/// closures over this, so the grant/charging arithmetic cannot diverge
/// between the paths whose bit-identity the tests pin.
fn execute_windows_with(
    job: &ChainJob,
    windows: &WindowAllocation,
    selfowned: SelfOwnedRule,
    mut pool: Option<&mut SelfOwnedPool>,
    mut exec: impl FnMut(f64, f64, f64, f64, u32) -> (usize, TaskOutcome),
) -> RoutedChainOutcome {
    assert_eq!(windows.sizes.len(), job.num_tasks());
    let mut ledger = CostLedger::new();
    let mut tasks = Vec::with_capacity(job.num_tasks());
    let mut task_offers = Vec::with_capacity(job.num_tasks());
    let mut t = job.arrival;
    let mut deadline_cursor = job.arrival;

    for (task, &size) in job.tasks.iter().zip(&windows.sizes) {
        deadline_cursor += size;
        let deadline = deadline_cursor;
        let start = t.min(deadline); // early finishes only move starts earlier
        let hat_s = deadline - start;

        // Self-owned grant for [start, deadline].
        let r = match (selfowned, pool.as_deref_mut()) {
            (SelfOwnedRule::None, _) | (_, None) => 0,
            (SelfOwnedRule::Rule12 { beta0 }, Some(p)) => {
                let n = p.available_over(start, deadline);
                let r = rule12(task.size, task.parallelism, hat_s, beta0, n);
                let ok = p.reserve(r, start, deadline);
                debug_assert!(ok, "rule12 grant exceeded pool");
                r
            }
            (SelfOwnedRule::Naive, Some(p)) => {
                let n = p.available_over(start, deadline);
                let r = naive_allocation(task.parallelism, n);
                let ok = p.reserve(r, start, deadline);
                debug_assert!(ok, "naive grant exceeded pool");
                r
            }
        };

        let (offer, outcome) = exec(task.size, task.parallelism, start, deadline, r);
        ledger.charge(InstanceKind::SelfOwned, 1.0, outcome.so_work, 0.0);
        ledger.charge(InstanceKind::Spot, 1.0, outcome.spot_work, 0.0);
        ledger.cost_spot += outcome.spot_cost;
        ledger.charge(InstanceKind::OnDemand, 1.0, outcome.od_work, 0.0);
        ledger.cost_ondemand += outcome.od_cost;
        t = outcome.finish;
        tasks.push(outcome);
        task_offers.push(offer);
    }

    RoutedChainOutcome {
        outcome: JobOutcome {
            job_id: job.id,
            finish: t,
            met_deadline: t <= job.deadline + 1e-6,
            ledger,
            tasks,
        },
        task_offers,
    }
}

/// Execute a whole chain job under a strategy.
///
/// `pool` supplies self-owned instances; reservations are made at each
/// task's realized start over `[start, ς_i]` and are permanent for the
/// window (the paper holds them through the task deadline).
pub fn execute_chain(
    job: &ChainJob,
    strategy: &ChainStrategy,
    trace: &PriceTrace,
    pool: Option<&mut SelfOwnedPool>,
    od_price: f64,
) -> JobOutcome {
    match strategy {
        ChainStrategy::Windows {
            windows,
            selfowned,
            bid,
        } => execute_windows(job, windows, *selfowned, *bid, trace, pool, od_price),
        ChainStrategy::Greedy { bid } => execute_greedy(job, *bid, trace, od_price),
    }
}

fn execute_windows(
    job: &ChainJob,
    windows: &WindowAllocation,
    selfowned: SelfOwnedRule,
    bid: f64,
    trace: &PriceTrace,
    pool: Option<&mut SelfOwnedPool>,
    od_price: f64,
) -> JobOutcome {
    execute_windows_with(job, windows, selfowned, pool, |z, delta, start, deadline, r| {
        (
            0,
            execute_task(z, delta, start, deadline, r, bid, trace, od_price),
        )
    })
    .outcome
}

fn execute_greedy(job: &ChainJob, bid: f64, trace: &PriceTrace, od_price: f64) -> JobOutcome {
    let mut ledger = CostLedger::new();
    let mut remaining: Vec<(f64, f64)> = job
        .tasks
        .iter()
        .map(|t| (t.size, t.parallelism))
        .collect();
    let mut tasks: Vec<TaskOutcome> = job
        .tasks
        .iter()
        .map(|_task| TaskOutcome {
            start: job.arrival,
            deadline: job.deadline,
            finish: job.arrival,
            r: 0,
            so_work: 0.0,
            spot_work: 0.0,
            od_work: 0.0,
            spot_cost: 0.0,
            od_cost: 0.0,
        })
        .collect();

    let dt = trace.slot_len();
    let mut t = job.arrival;
    let mut cur = 0usize;
    let finish;
    if !remaining.is_empty() {
        tasks[0].start = t;
    }
    loop {
        if cur >= remaining.len() {
            finish = t;
            break;
        }
        let rem_slice = &remaining[cur..];
        if greedy_must_switch(rem_slice, job.deadline - t) {
            // Switch: every remaining task runs at full δ on-demand,
            // sequentially.
            let mut tt = t;
            for (k, &(z, delta)) in rem_slice.iter().enumerate() {
                let idx = cur + k;
                if k > 0 {
                    tasks[idx].start = tt;
                }
                tasks[idx].od_work += z;
                tasks[idx].od_cost += od_price * z;
                ledger.charge(InstanceKind::OnDemand, 1.0, z, 0.0);
                ledger.cost_ondemand += od_price * z;
                tt += z / delta;
                tasks[idx].finish = tt;
            }
            finish = tt;
            break;
        }
        let (z, delta) = remaining[cur];
        let mut slot_end = ((t / dt).floor() + 1.0) * dt;
        while slot_end <= t {
            slot_end += dt;
        }
        let price = trace.price_at(t + EPS);
        if price <= bid {
            let t_fin = t + z / delta;
            let upto = slot_end.min(t_fin);
            let dw = delta * (upto - t);
            tasks[cur].spot_work += dw;
            tasks[cur].spot_cost += price * dw;
            ledger.charge(InstanceKind::Spot, 1.0, dw, 0.0);
            ledger.cost_spot += price * dw;
            remaining[cur].0 -= dw;
            t = upto;
            if remaining[cur].0 <= EPS {
                tasks[cur].finish = t;
                cur += 1;
                if cur < remaining.len() {
                    tasks[cur].start = t;
                }
            }
        } else {
            // No progress; the switch moment is when cp == remaining window.
            let cp: f64 = rem_slice.iter().map(|(z, d)| z / d).sum();
            let t_switch = job.deadline - cp;
            t = if t_switch <= slot_end + EPS {
                t_switch.max(t)
            } else {
                slot_end
            };
        }
    }

    JobOutcome {
        job_id: job.id,
        finish,
        met_deadline: finish <= job.deadline + 1e-6,
        ledger,
        tasks,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::market::SLOTS_PER_UNIT;
    use crate::policy::dealloc::dealloc;
    use crate::util::prop::{for_all, Config};
    use crate::util::rng::Pcg32;
    use crate::workload::ChainTask;

    /// Trace where spot is always available at a flat price.
    fn always(price: f64, horizon: f64) -> PriceTrace {
        let n = (horizon * SLOTS_PER_UNIT as f64) as usize + 2;
        PriceTrace::from_prices(vec![price; n], 1.0 / SLOTS_PER_UNIT as f64)
    }

    /// Trace where spot is never available.
    fn never(horizon: f64) -> PriceTrace {
        let n = (horizon * SLOTS_PER_UNIT as f64) as usize + 2;
        PriceTrace::from_prices(vec![f64::INFINITY; n], 1.0 / SLOTS_PER_UNIT as f64)
    }

    /// Alternating available/unavailable slots (β ≈ 0.5 at bid 0.5).
    fn alternating(horizon: f64) -> PriceTrace {
        let n = (horizon * SLOTS_PER_UNIT as f64) as usize + 2;
        let prices = (0..n)
            .map(|i| if i % 2 == 0 { 0.2 } else { 0.9 })
            .collect();
        PriceTrace::from_prices(prices, 1.0 / SLOTS_PER_UNIT as f64)
    }

    #[test]
    fn all_spot_when_always_available() {
        let trace = always(0.2, 10.0);
        let o = execute_task(2.0, 2.0, 0.0, 4.0, 0, 0.3, &trace, 1.0);
        assert!((o.spot_work - 2.0).abs() < 1e-9);
        assert_eq!(o.od_work, 0.0);
        assert!((o.spot_cost - 0.4).abs() < 1e-9);
        assert!((o.finish - 1.0).abs() < 1e-9); // z/δ = 1 at full parallelism
    }

    #[test]
    fn all_ondemand_when_never_available() {
        let trace = never(10.0);
        // window exactly e: turning point at start.
        let o = execute_task(2.0, 2.0, 0.0, 1.0, 0, 0.3, &trace, 1.0);
        assert_eq!(o.spot_work, 0.0);
        assert!((o.od_work - 2.0).abs() < 1e-9);
        assert!((o.od_cost - 2.0).abs() < 1e-9);
        assert!((o.finish - 1.0).abs() < 1e-9);
    }

    #[test]
    fn turning_point_fires_exactly_at_deadline_feasibility() {
        // Never-available spot with slack: waits until the exact turning
        // point, then on-demand finishes exactly at the deadline.
        let trace = never(10.0);
        let o = execute_task(2.0, 2.0, 0.0, 3.0, 0, 0.3, &trace, 1.0);
        assert_eq!(o.spot_work, 0.0);
        assert!((o.od_work - 2.0).abs() < 1e-9);
        assert!((o.finish - 3.0).abs() < 1e-6);
        assert!(o.finish <= 3.0 + 1e-6);
    }

    #[test]
    fn alternating_slots_give_half_spot() {
        // Window big enough to never hit the turning point: everything on
        // spot, finishing takes ~2e (half the slots win).
        let trace = alternating(20.0);
        let (z, delta) = (2.0, 2.0); // e = 1
        let o = execute_task(z, delta, 0.0, 10.0, 0, 0.5, &trace, 1.0);
        assert!((o.spot_work - z).abs() < 1e-9);
        assert_eq!(o.od_work, 0.0);
        assert!((o.finish - 2.0).abs() < 0.1, "finish={}", o.finish);
        // cost = z * 0.2 (only cheap slots won)
        assert!((o.spot_cost - 0.4).abs() < 1e-9);
    }

    #[test]
    fn deadline_always_met_on_feasible_windows() {
        for_all(Config::cases(300).seed(21), |rng| {
            let delta = rng.uniform(1.0, 64.0);
            let e = rng.uniform(0.1, 4.0);
            let z = e * delta;
            let hat_s = e * rng.uniform(1.0, 3.0);
            let bid = rng.uniform(0.1, 0.4);
            let trace = random_trace(rng, hat_s + 1.0);
            let o = execute_task(z, delta, 0.0, hat_s, 0, bid, &trace, 1.0);
            if o.finish > hat_s + 1e-6 {
                return Err(format!("deadline missed: {} > {hat_s}", o.finish));
            }
            let processed = o.spot_work + o.od_work + o.so_work;
            if (processed - z).abs() > 1e-6 * z.max(1.0) {
                return Err(format!("workload not conserved: {processed} vs {z}"));
            }
            Ok(())
        });
    }

    #[test]
    fn selfowned_reduces_cloud_work() {
        let trace = never(10.0);
        // r=1 over window [0,2] with z=5.5, δ=3 (§3.3.1 toy example b).
        let o = execute_task(5.5, 3.0, 0.0, 2.0, 1, 0.3, &trace, 1.0);
        assert!((o.so_work - 2.0).abs() < 1e-9);
        assert!((o.od_work - 3.5).abs() < 1e-9);
        assert_eq!(o.finish, 2.0);
    }

    #[test]
    fn toy_example_fig2a_no_turning_point() {
        // §3.3.1: z=3.5, δ=3, r=1, window [0,2], β=0.5 via alternating
        // slots: z̃=1.5 processed by spot (1 instance-pair alternating) and
        // one on-demand? In the paper o_i = s_i = 1; our executor is the
        // expected-optimal all-spot variant (Prop. 4.1), so spot does all
        // of z̃ = 1.5.
        let trace = alternating(10.0);
        let o = execute_task(3.5, 3.0, 0.0, 2.0, 1, 0.5, &trace, 1.0);
        assert!((o.so_work - 2.0).abs() < 1e-9);
        assert!(o.spot_work > 0.0);
        assert!(
            (o.spot_work + o.od_work - 1.5).abs() < 1e-9,
            "cloud work {}",
            o.spot_work + o.od_work
        );
        assert_eq!(o.finish, 2.0);
    }

    #[test]
    fn chain_execution_matches_paper_example_under_perfect_spot() {
        let job = ChainJob::paper_example();
        let windows = dealloc(&job, 0.5);
        let trace = always(0.2, 10.0);
        let o = execute_chain(
            &job,
            &ChainStrategy::Windows {
                windows: &windows,
                selfowned: SelfOwnedRule::None,
                bid: 0.3,
            },
            &trace,
            None,
            1.0,
        );
        // Perfect spot: everything on spot, early finishes cascade.
        assert!((o.ledger.work_spot - 5.0).abs() < 1e-9);
        assert_eq!(o.ledger.work_ondemand, 0.0);
        assert!(o.met_deadline);
        assert!(o.finish < 4.0);
    }

    #[test]
    fn chain_deadlines_respected_under_any_trace() {
        for_all(Config::cases(150).seed(22), |rng| {
            let job = random_job(rng);
            let beta = rng.uniform(0.2, 1.0);
            let windows = dealloc(&job, beta);
            let trace = random_trace(rng, job.deadline + 1.0);
            let o = execute_chain(
                &job,
                &ChainStrategy::Windows {
                    windows: &windows,
                    selfowned: SelfOwnedRule::None,
                    bid: rng.uniform(0.1, 0.4),
                },
                &trace,
                None,
                1.0,
            );
            if !o.met_deadline {
                return Err(format!("missed deadline: {} > {}", o.finish, job.deadline));
            }
            let total = o.ledger.total_work();
            if (total - job.total_work()).abs() > 1e-6 * job.total_work() {
                return Err(format!("work {total} != {}", job.total_work()));
            }
            Ok(())
        });
    }

    #[test]
    fn greedy_meets_deadline_and_conserves_work() {
        for_all(Config::cases(150).seed(23), |rng| {
            let job = random_job(rng);
            let trace = random_trace(rng, job.deadline + 1.0);
            let o = execute_chain(
                &job,
                &ChainStrategy::Greedy {
                    bid: rng.uniform(0.1, 0.4),
                },
                &trace,
                None,
                1.0,
            );
            if !o.met_deadline {
                return Err(format!("greedy missed: {} > {}", o.finish, job.deadline));
            }
            let total = o.ledger.total_work();
            if (total - job.total_work()).abs() > 1e-6 * job.total_work() {
                return Err(format!("work {total} != {}", job.total_work()));
            }
            Ok(())
        });
    }

    #[test]
    fn greedy_all_spot_when_available() {
        let job = ChainJob::paper_example();
        let trace = always(0.2, 10.0);
        let o = execute_chain(&job, &ChainStrategy::Greedy { bid: 0.3 }, &trace, None, 1.0);
        assert!((o.ledger.work_spot - 5.0).abs() < 1e-9);
        assert!((o.finish - job.min_makespan()).abs() < 1e-9);
    }

    #[test]
    fn greedy_never_available_switches_at_right_time() {
        let job = ChainJob::paper_example(); // cp = 2.5833, window 4
        let trace = never(10.0);
        let o = execute_chain(&job, &ChainStrategy::Greedy { bid: 0.3 }, &trace, None, 1.0);
        // Switch at t = 4 − 2.5833…; everything on-demand; finish = 4.
        assert_eq!(o.ledger.work_spot, 0.0);
        assert!((o.ledger.work_ondemand - 5.0).abs() < 1e-9);
        assert!((o.finish - 4.0).abs() < 1e-6);
    }

    #[test]
    fn pool_contention_between_tasks() {
        let mut pool = SelfOwnedPool::new(2, 20.0, 1.0 / SLOTS_PER_UNIT as f64);
        let job = ChainJob::new(
            0,
            0.0,
            4.0,
            vec![ChainTask::new(4.0, 4.0), ChainTask::new(4.0, 4.0)],
        );
        let windows = dealloc(&job, 0.5);
        let trace = never(10.0);
        let o = execute_chain(
            &job,
            &ChainStrategy::Windows {
                windows: &windows,
                selfowned: SelfOwnedRule::Naive,
                bid: 0.3,
            },
            &trace,
            Some(&mut pool),
            1.0,
        );
        // Naive takes min(N, δ) = 2 instances in both windows.
        assert_eq!(o.tasks[0].r, 2);
        assert_eq!(o.tasks[1].r, 2);
        assert!(o.ledger.work_selfowned > 0.0);
        assert!(o.met_deadline);
    }

    #[test]
    fn one_offer_routed_chain_matches_legacy_exactly() {
        // The acceptance contract: a one-offer infinite-capacity view must
        // reproduce the single-trace executor bit-for-bit, under every
        // routing policy.
        use crate::market::{CapacityLedger, MarketView};
        use crate::policy::routing::RoutingPolicy;
        for_all(Config::cases(120).seed(24), |rng| {
            let job = random_job(rng);
            let windows = dealloc(&job, rng.uniform(0.2, 1.0));
            let bid = rng.uniform(0.1, 0.4);
            let trace = random_trace(rng, job.deadline + 1.0);
            let legacy = execute_chain(
                &job,
                &ChainStrategy::Windows {
                    windows: &windows,
                    selfowned: SelfOwnedRule::None,
                    bid,
                },
                &trace,
                None,
                1.0,
            );
            let view = MarketView::single(trace.clone(), 1.0);
            for routing in [
                RoutingPolicy::Home,
                RoutingPolicy::CheapestFeasible,
                RoutingPolicy::Spillover,
            ] {
                let mut cap = CapacityLedger::new(&view, job.deadline + 1.0);
                let routed = execute_chain_routed(
                    &job,
                    &windows,
                    SelfOwnedRule::None,
                    bid,
                    &view,
                    &mut cap,
                    routing,
                    None,
                );
                if routed.task_offers.iter().any(|&o| o != 0) {
                    return Err("one-offer view routed off offer 0".into());
                }
                if routed.outcome.cost() != legacy.cost()
                    || routed.outcome.finish != legacy.finish
                    || routed.outcome.ledger.work_spot != legacy.ledger.work_spot
                    || routed.outcome.ledger.work_ondemand != legacy.ledger.work_ondemand
                {
                    return Err(format!(
                        "{routing:?}: routed ({}, {}) != legacy ({}, {})",
                        routed.outcome.cost(),
                        routed.outcome.finish,
                        legacy.cost(),
                        legacy.finish
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn capacity_exhaustion_degrades_to_on_demand_not_deadline_miss() {
        use crate::market::{CapacityLedger, MarketOffer, MarketView};
        use crate::policy::routing::RoutingPolicy;
        // One cheap always-available offer with room for a single task.
        let n = (20.0 * SLOTS_PER_UNIT as f64) as usize + 2;
        let view = MarketView::new(vec![MarketOffer {
            region: "tiny".into(),
            instance_type: "default".into(),
            od_price: 1.0,
            trace: PriceTrace::from_prices(vec![0.2; n], 1.0 / SLOTS_PER_UNIT as f64),
            capacity: Some(2),
        }])
        .unwrap();
        let mut cap = CapacityLedger::new(&view, 20.0);
        // First task takes both units over [0, 4].
        let (o1, out1) = execute_task_routed(2.0, 2.0, 0.0, 4.0, 0, 0.3, &view, &mut cap, RoutingPolicy::CheapestFeasible);
        assert_eq!(o1, 0);
        assert!(out1.spot_work > 0.0);
        // Second concurrent task finds no spot capacity: all on-demand,
        // deadline still met.
        let (o2, out2) = execute_task_routed(2.0, 2.0, 0.0, 2.0, 0, 0.3, &view, &mut cap, RoutingPolicy::CheapestFeasible);
        assert_eq!(o2, 0);
        assert_eq!(out2.spot_work, 0.0);
        assert!((out2.od_work - 2.0).abs() < 1e-9);
        assert!(out2.finish <= 2.0 + 1e-6);
    }

    #[test]
    fn routed_task_charges_the_offer_it_ran_on() {
        use crate::market::{CapacityLedger, MarketOffer, MarketView};
        use crate::policy::routing::RoutingPolicy;
        let n = (10.0 * SLOTS_PER_UNIT as f64) as usize + 2;
        let dt = 1.0 / SLOTS_PER_UNIT as f64;
        let view = MarketView::new(vec![
            MarketOffer {
                region: "pricey".into(),
                instance_type: "default".into(),
                od_price: 1.0,
                trace: PriceTrace::from_prices(vec![0.8; n], dt),
                capacity: None,
            },
            MarketOffer {
                region: "cheap".into(),
                instance_type: "default".into(),
                od_price: 1.2,
                trace: PriceTrace::from_prices(vec![0.2; n], dt),
                capacity: None,
            },
        ])
        .unwrap();
        let mut cap = CapacityLedger::new(&view, 10.0);
        let (offer, out) = execute_task_routed(2.0, 2.0, 0.0, 4.0, 0, 0.9, &view, &mut cap, RoutingPolicy::CheapestFeasible);
        assert_eq!(offer, 1, "cheapest spot price wins");
        // Cost reflects the cheap offer's 0.2 spot price, not 0.8.
        assert!((out.spot_cost - 0.4).abs() < 1e-9, "cost {}", out.spot_cost);
    }

    /// Two-offer view with opposite-phase price epochs: offer 0 cheap in
    /// even epochs, offer 1 cheap in odd epochs (`epoch` slots each).
    fn seesaw_view(horizon: f64, epoch: usize, lo: f64, hi: f64) -> MarketView {
        use crate::market::MarketOffer;
        let n = (horizon * SLOTS_PER_UNIT as f64) as usize + 2;
        let dt = 1.0 / SLOTS_PER_UNIT as f64;
        let a: Vec<f64> = (0..n)
            .map(|i| if (i / epoch) % 2 == 0 { lo } else { hi })
            .collect();
        let b: Vec<f64> = (0..n)
            .map(|i| if (i / epoch) % 2 == 0 { hi } else { lo })
            .collect();
        MarketView::new(vec![
            MarketOffer {
                region: "even".into(),
                instance_type: "default".into(),
                od_price: 1.0,
                trace: PriceTrace::from_prices(a, dt),
                capacity: None,
            },
            MarketOffer {
                region: "odd".into(),
                instance_type: "default".into(),
                od_price: 1.0,
                trace: PriceTrace::from_prices(b, dt),
                capacity: None,
            },
        ])
        .unwrap()
    }

    #[test]
    fn migration_chases_the_cheap_side_of_a_seesaw() {
        use crate::market::CapacityLedger;
        use crate::policy::routing::{MigrationPolicy, RoutingPolicy};
        // Both sides winnable at the bid; switch cost tiny: the walk should
        // hop to the cheap side at every epoch flip and pay ~lo everywhere.
        let view = seesaw_view(40.0, 4, 0.1, 0.6);
        let mut cap = CapacityLedger::new(&view, 40.0);
        let (d, out, migs) = execute_task_routed_migrating(
            8.0,
            1.0,
            0.0,
            10.0,
            0,
            0.9,
            &view,
            &mut cap,
            RoutingPolicy::CheapestFeasible,
            MigrationPolicy { switch_cost: 1e-6, hysteresis_slots: 0 },
        );
        assert_eq!(d.offer, 0, "even offer is cheap at t=0");
        assert!(!migs.is_empty(), "seesaw never triggered a migration");
        assert!((out.spot_work - 8.0).abs() < 1e-9);
        assert_eq!(out.od_work, 0.0);
        // All work at the cheap price, plus the tiny switch charges.
        let switch_total = migs.len() as f64 * 1e-6;
        assert!(
            (out.spot_cost - (0.8 + switch_total)).abs() < 1e-9,
            "cost {} with {} migrations",
            out.spot_cost,
            migs.len()
        );
        assert!(out.finish <= 10.0 + 1e-6);
        for w in migs.windows(2) {
            assert!(w[0].time <= w[1].time);
        }
        for m in &migs {
            assert_ne!(m.from_offer, m.to_offer);
            assert!(m.projected_saving > 0.0);
        }
    }

    #[test]
    fn migration_walk_never_misses_deadlines_or_loses_work() {
        use crate::market::CapacityLedger;
        use crate::policy::routing::{MigrationPolicy, RoutingPolicy};
        for_all(Config::cases(200).seed(29), |rng| {
            let delta = rng.uniform(1.0, 16.0);
            let e = rng.uniform(0.1, 3.0);
            let z = e * delta;
            let hat_s = e * rng.uniform(1.01, 3.0);
            let bid = rng.uniform(0.1, 0.5);
            let epoch = rng.range_inclusive(1, 6) as usize;
            let lo = rng.uniform(0.05, 0.3);
            let hi = rng.uniform(0.31, 1.2);
            let view = seesaw_view(hat_s + 2.0, epoch, lo, hi);
            let mut cap = CapacityLedger::new(&view, hat_s + 2.0);
            let (_, out, _) = execute_task_routed_migrating(
                z,
                delta,
                0.0,
                hat_s,
                0,
                bid,
                &view,
                &mut cap,
                RoutingPolicy::CheapestFeasible,
                MigrationPolicy {
                    switch_cost: rng.uniform(0.0, 0.05),
                    hysteresis_slots: rng.range_inclusive(0, 4) as u32,
                },
            );
            if out.finish > hat_s + 1e-6 {
                return Err(format!("deadline missed: {} > {hat_s}", out.finish));
            }
            let processed = out.spot_work + out.od_work + out.so_work;
            if (processed - z).abs() > 1e-6 * z.max(1.0) {
                return Err(format!("workload not conserved: {processed} vs {z}"));
            }
            Ok(())
        });
    }

    #[test]
    fn migration_disabled_matches_pinned_path_bitwise() {
        use crate::market::CapacityLedger;
        use crate::policy::routing::{MigrationPolicy, RoutingPolicy};
        // With switch_cost = +inf no switch can fire and the walk arithmetic
        // is expression-for-expression the pinned executor's, so outcomes
        // must be bitwise equal.
        for_all(Config::cases(150).seed(31), |rng| {
            let delta = rng.uniform(1.0, 16.0);
            let e = rng.uniform(0.1, 3.0);
            let z = e * delta;
            let hat_s = e * rng.uniform(1.01, 3.0);
            let bid = rng.uniform(0.1, 0.5);
            let view = seesaw_view(hat_s + 2.0, 3, 0.1, 0.8);
            for routing in [RoutingPolicy::CheapestFeasible, RoutingPolicy::Spillover] {
                let mut cap_a = CapacityLedger::new(&view, hat_s + 2.0);
                let (da, pinned) = execute_task_routed_decide(
                    z, delta, 0.0, hat_s, 0, bid, &view, &mut cap_a, routing,
                );
                let mut cap_b = CapacityLedger::new(&view, hat_s + 2.0);
                let (db, migr, recs) = execute_task_routed_migrating(
                    z,
                    delta,
                    0.0,
                    hat_s,
                    0,
                    bid,
                    &view,
                    &mut cap_b,
                    routing,
                    MigrationPolicy::disabled(),
                );
                if !recs.is_empty() {
                    return Err("disabled migration recorded a switch".into());
                }
                if da != db || pinned != migr {
                    return Err(format!(
                        "{routing:?}: disabled-migration walk diverged: {migr:?} vs {pinned:?}"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn hysteresis_is_monotone_in_migration_count() {
        use crate::market::CapacityLedger;
        use crate::policy::routing::{MigrationPolicy, RoutingPolicy};
        // Seesaw with both sides winnable and a negligible switch cost:
        // progress runs at full rate on either offer, so the remaining-work
        // trajectory is hysteresis-independent and switch times under a
        // larger hysteresis dominate those under a smaller one pointwise —
        // the migration count is non-increasing in `hysteresis_slots`.
        let view = seesaw_view(60.0, 3, 0.1, 0.5);
        let mut last = usize::MAX;
        for h in [0u32, 1, 2, 4, 8, 16, 64, 10_000] {
            let mut cap = CapacityLedger::new(&view, 60.0);
            let (_, out, migs) = execute_task_routed_migrating(
                20.0,
                1.0,
                0.0,
                30.0,
                0,
                0.9,
                &view,
                &mut cap,
                RoutingPolicy::CheapestFeasible,
                MigrationPolicy { switch_cost: 1e-9, hysteresis_slots: h },
            );
            assert!(out.finish <= 30.0 + 1e-6);
            assert!(
                migs.len() <= last,
                "hysteresis {h}: {} migrations > previous {last}",
                migs.len()
            );
            last = migs.len();
        }
        // The first switch is never hysteresis-gated, so the floor is one
        // move (off the expensive side at the first flip), not zero.
        assert!(last <= 1, "effectively-infinite hysteresis took {last} moves");
    }

    fn random_job(rng: &mut Pcg32) -> ChainJob {
        let l = rng.range_inclusive(1, 6) as usize;
        let tasks: Vec<ChainTask> = (0..l)
            .map(|_| ChainTask::new(rng.uniform(0.3, 4.0), rng.uniform(1.0, 16.0)))
            .collect();
        let makespan: f64 = tasks.iter().map(|t| t.min_exec_time()).sum();
        ChainJob::new(0, 0.0, makespan * rng.uniform(1.01, 3.0), tasks)
    }

    fn random_trace(rng: &mut Pcg32, horizon: f64) -> PriceTrace {
        let n = (horizon * SLOTS_PER_UNIT as f64) as usize + 2;
        let prices = (0..n)
            .map(|_| {
                if rng.chance(0.5) {
                    rng.uniform(0.12, 0.25)
                } else {
                    rng.uniform(0.5, 1.0)
                }
            })
            .collect();
        PriceTrace::from_prices(prices, 1.0 / SLOTS_PER_UNIT as f64)
    }
}
