//! Discrete-event simulation of job execution against realized spot-price
//! traces.
//!
//! [`executor`] runs a single task / chain job under a strategy
//! (Definitions 3.1/3.2, Algorithm 2, or the Greedy baseline);
//! [`horizon`] runs a whole arriving workload with a shared self-owned pool
//! in event order; [`cost`] computes the paper's evaluation metrics
//! (`α`, `ρ`, `μ`).

pub mod executor;
pub mod horizon;
pub mod cost;

pub use executor::{
    execute_chain, execute_chain_routed, execute_task_routed, spot_units, ChainStrategy,
    JobOutcome, RoutedChainOutcome, SelfOwnedRule, TaskOutcome,
};
pub use horizon::{HorizonReport, HorizonRunner, StrategySpec};
