#!/usr/bin/env python3
"""Bench-regression gate: compare the current run's BENCH_*.json files
against the previous successful run's artifacts and fail on a hot-path
slowdown beyond the threshold.

Usage:
    bench_gate.py --baseline DIR --current DIR [--threshold 0.15]

Both directories hold BENCH_<name>.json files as produced by the Rust
bench harness (an array of rows: {"name", "iters", "mean_ns", "p50_ns",
"p95_ns", ...}). Rows are matched across runs by their "name" field,
file by file; a row or file present on only one side is reported but
never fails the gate (benches come and go; the gate only guards rows
that exist on both sides).

A missing or empty baseline directory passes with a notice — the first
run on a branch, or an expired artifact, must not brick CI. CI noise is
real on shared runners, so the default threshold is deliberately
generous (15% on mean_ns); catching 2x regressions reliably beats
flagging 5% ones noisily.
"""

import argparse
import json
import pathlib
import sys


def load_rows(path):
    """BENCH file -> {bench name: mean_ns}, skipping malformed rows."""
    try:
        data = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"  warning: unreadable bench file {path}: {e}")
        return {}
    rows = {}
    if not isinstance(data, list):
        print(f"  warning: {path} is not a bench row array")
        return {}
    for row in data:
        name = row.get("name") if isinstance(row, dict) else None
        mean = row.get("mean_ns") if isinstance(row, dict) else None
        if isinstance(name, str) and isinstance(mean, (int, float)) and mean > 0:
            rows[name] = float(mean)
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True, help="previous run's BENCH dir")
    ap.add_argument("--current", required=True, help="this run's BENCH dir")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.15,
        help="max tolerated mean_ns increase (fraction, default 0.15)",
    )
    args = ap.parse_args()

    base_dir = pathlib.Path(args.baseline)
    cur_dir = pathlib.Path(args.current)
    cur_files = sorted(cur_dir.glob("BENCH_*.json"))
    if not cur_files:
        print(f"no BENCH_*.json under {cur_dir}; nothing to gate")
        return 1
    if not base_dir.is_dir() or not any(base_dir.rglob("BENCH_*.json")):
        print(f"no baseline artifacts under {base_dir}; passing (first run or expired)")
        return 0

    failures = []
    compared = 0
    for cur_file in cur_files:
        base_file = base_dir / cur_file.name
        if not base_file.exists():
            # Artifact downloads may nest each artifact in its own
            # directory; accept BENCH_foo/BENCH_foo.json too.
            nested = base_dir / cur_file.stem / cur_file.name
            if nested.exists():
                base_file = nested
            else:
                print(f"  {cur_file.name}: no baseline counterpart (new bench file)")
                continue
        base_rows = load_rows(base_file)
        cur_rows = load_rows(cur_file)
        for name, cur_mean in sorted(cur_rows.items()):
            if name not in base_rows:
                print(f"  {cur_file.name}: '{name}' is new (no baseline row)")
                continue
            base_mean = base_rows[name]
            ratio = cur_mean / base_mean - 1.0
            compared += 1
            marker = "OK "
            if ratio > args.threshold:
                marker = "FAIL"
                failures.append((name, base_mean, cur_mean, ratio))
            print(
                f"  [{marker}] {name}: {base_mean:.0f} -> {cur_mean:.0f} ns "
                f"({ratio:+.1%})"
            )

    print(f"compared {compared} bench row(s), threshold {args.threshold:.0%}")
    if failures:
        print(f"{len(failures)} hot-path regression(s) beyond the threshold:")
        for name, base_mean, cur_mean, ratio in failures:
            print(f"  {name}: {base_mean:.0f} -> {cur_mean:.0f} ns ({ratio:+.1%})")
        return 1
    print("bench gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
